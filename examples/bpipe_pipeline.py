"""BPipe in action: train a model under GPipe / 1F1B / BPipe pipeline
schedules — plain and interleaved (v virtual chunks per stage) — and
print the per-stage activation-stash peaks: the paper's Fig. 1, live.

    PYTHONPATH=src python examples/bpipe_pipeline.py [--stages 4] [--v 2]

All schedules produce bit-comparable losses (same math, different
memory); the printed peaks show 1F1B's p-x imbalance, BPipe's
ceil((p+2)/2) cap, interleaving's stash growth, and the interleaved
BPipe cap clawing it back.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import TrainConfig  # noqa: E402
from repro.core import schedule as S  # noqa: E402
from repro.data.pipeline import DataConfig, make_batch  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim import adam  # noqa: E402
from repro.pipeline import PipelineExecutor  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--v", type=int, default=2,
                    help="virtual chunks per stage for interleaved kinds")
    args = ap.parse_args()
    p = args.stages

    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=max(2, args.v) * p, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    dc = DataConfig(batch=8, seq_len=32)
    tcfg = TrainConfig(global_batch=8, steps=args.steps, warmup_steps=1,
                       learning_rate=1e-3)

    m = 8 // args.micro
    print(f"pipeline: p={p}, m={m} microbatches, "
          f"BPipe cap = ceil((p+2)/2) = {S.bpipe_cap(p)}, "
          f"interleaved (v={args.v}) cap = {S.bpipe_interleaved_cap(p, args.v)}")
    kinds = ["gpipe", "1f1b", "bpipe"]
    # interleaved streams need m to be a multiple of p and v >= 2
    if m % p == 0 and args.v >= 2:
        kinds += ["1f1b_interleaved", "bpipe_interleaved"]
    for kind in kinds:
        ex = PipelineExecutor(cfg, p=p, kind=kind, micro_batch=args.micro,
                              v=args.v)
        params_k, opt = params, adam.init(params)
        losses = []
        stats = None
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dc, i).items()}
            res = ex.step(params_k, batch)
            params_k, opt, _ = adam.update(params_k, res.grads, opt, tcfg)
            losses.append(float(res.loss))
            stats = res.stats
        peaks = [stats.peak_local[i] for i in range(p)]
        print(f"{kind:>6}: losses {['%.3f' % l for l in losses]}")
        print(f"        peak stash/stage {peaks}  "
              f"evictions={stats.evictions} loads={stats.loads} "
              f"moved={stats.bytes_moved/2**20:.1f}MiB(modelled)")


if __name__ == "__main__":
    main()
