"""BPipe in action: train a model under GPipe / 1F1B / BPipe pipeline
schedules — plain and interleaved (v virtual chunks per stage) — and
print the per-stage activation-stash peaks: the paper's Fig. 1, live.

    PYTHONPATH=src python examples/bpipe_pipeline.py [--stages 4] [--v 2]
    PYTHONPATH=src python examples/bpipe_pipeline.py --plan auto

All schedules produce bit-comparable losses (same math, different
memory); the printed peaks show 1F1B's p-x imbalance, BPipe's
ceil((p+2)/2) cap, interleaving's stash growth, and the interleaved
BPipe cap clawing it back.

``--plan auto`` demonstrates the full planner loop instead of sweeping
every kind by hand: the auto-planner picks the schedule under a toy HBM
budget, the executor runs it, the last step is traced, and the trace is
fed back through ``planner.calibrate`` to re-ground the simulator in
measured Tf/Tb (plan -> build -> execute -> trace -> recalibrate).
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.base import TrainConfig  # noqa: E402
from repro.core import schedule as S  # noqa: E402
from repro.core.plan import ScheduleSpec  # noqa: E402
from repro.data.pipeline import DataConfig, make_batch  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim import adam  # noqa: E402
from repro.pipeline import PipelineExecutor  # noqa: E402


def auto_plan(cfg, p, v, batch_rows, seq):
    """Ask the planner for the schedule instead of picking one by hand."""
    from repro.core import memory_model as MM
    from repro.core.notation import Notation
    from repro.planner import SearchSpace, plan_config, recommend, report

    n = Notation(a=cfg.num_heads, b=1, h=cfg.d_model, l=cfg.num_layers,
                 s=seq, v=cfg.vocab_size, B=batch_rows, p=p, t=1)
    # a toy budget tight enough that fat stashes actually prune
    budget = 1.2 * MM.max_stage_bytes(n, "none", "1f1b", cfg)
    search = SearchSpace(attentions=("none",), vs=(v,) if v >= 2 else (2,))
    ranked = plan_config(n, cfg, budget, search=search, workspace=0.0)
    print(f"planner: {len(ranked)} candidates under "
          f"{budget / 2**20:.0f} MiB/device")
    print(report.format_table(ranked, top=6))
    print(report.recommendation_line(cfg.name, ranked, "none"))
    return recommend(ranked, "none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--v", type=int, default=2,
                    help="virtual chunks per stage for interleaved kinds")
    ap.add_argument("--plan", default="all", choices=["all", "auto"],
                    help="all: sweep every kind; auto: let repro.planner "
                         "pick, then trace + recalibrate")
    args = ap.parse_args()
    p = args.stages

    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=max(2, args.v) * p, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    dc = DataConfig(batch=8, seq_len=32)
    tcfg = TrainConfig(global_batch=8, steps=args.steps, warmup_steps=1,
                       learning_rate=1e-3)

    m = 8 // args.micro
    print(f"pipeline: p={p}, m={m} microbatches, "
          f"BPipe cap = ceil((p+2)/2) = {S.bpipe_cap(p)}, "
          f"interleaved (v={args.v}) cap = {S.bpipe_interleaved_cap(p, args.v)}")

    # Each variant is a first-class ScheduleSpec: the executor, simulator
    # and planner all consume the same compiled plan object.
    if args.plan == "auto":
        best = auto_plan(cfg, p, args.v, 8, 32)
        assert best is not None, "no feasible plan under the toy budget"
        args.micro = best.cand.b
        m = 8 // args.micro
        specs = [best.cand.spec(p)]
    else:
        arms = [("gpipe", "none"), ("1f1b", "none"), ("bpipe", "none")]
        # the other two residency mechanisms on the same 1F1B schedule:
        # host offload (real device_put) and selective recompute
        arms += [("1f1b", "host_offload"), ("1f1b", "selective_recompute")]
        # interleaved streams need m to be a multiple of p and v >= 2
        if m % p == 0 and args.v >= 2:
            arms += [("1f1b_interleaved", "none"),
                     ("bpipe_interleaved", "none")]
        specs = [ScheduleSpec(kind, p, m, v=args.v, residency=res)
                 for kind, res in arms]
    for spec in specs:
        kind = spec.kind if spec.residency in ("none", "bpipe_swap") \
            else f"{spec.kind}+{spec.residency}"
        ex = PipelineExecutor(cfg, spec=spec, micro_batch=args.micro)
        params_k, opt = params, adam.init(params)
        losses = []
        stats = None
        events = None
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dc, i).items()}
            trace = args.plan == "auto" and i == args.steps - 1
            res = ex.step(params_k, batch, trace=trace)
            params_k, opt, _ = adam.update(params_k, res.grads, opt, tcfg)
            losses.append(float(res.loss))
            stats = res.stats
            events = res.events or events
        peaks = [stats.peak_local[i] for i in range(p)]
        print(f"{kind:>6}: losses {['%.3f' % l for l in losses]}")
        moves = (f"evictions={stats.evictions} loads={stats.loads}"
                 if stats.offloads == stats.drops == 0 else
                 f"offloads={stats.offloads} fetches={stats.fetches} "
                 f"drops={stats.drops} recomputes={stats.recomputes}")
        print(f"        peak stash/stage {peaks}  {moves} "
              f"moved={stats.bytes_moved/2**20:.1f}MiB(modelled)")
        if events:
            # close the loop: trace -> recalibrate -> simulate
            from repro.planner import calibrate
            costs = calibrate.fit_trace(events, v=ex.v, b=args.micro)
            replayed = calibrate.replay(costs, spec)
            print(f"        recalibrated from trace: Tf={costs.Tf*1e3:.1f}ms "
                  f"Tb={costs.Tb*1e3:.1f}ms -> simulated step "
                  f"{replayed.makespan*1e3:.0f}ms "
                  f"(traced step {max(e.end for e in events)*1e3:.0f}ms)")


if __name__ == "__main__":
    main()
