"""Serve a small model with batched requests: prefill + greedy decode
through the KV-cache serve path (the same code the decode dry-runs lower).

    PYTHONPATH=src python examples/serve.py --arch recurrentgemma-2b

Works for every assigned family, including hybrid (ring-buffer local
attention + RG-LRU state) and SSM (xLSTM state) caches.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.train.steps import make_serve_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, sp = args.batch, args.prompt_len
    max_len = sp + args.gen + cfg.num_prefix_embeds

    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, sp), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    npre = cfg.num_prefix_embeds if cfg.frontend == "vision" else 0
    if npre:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, npre, cfg.d_model))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, 16, cfg.d_model))

    state = M.init_decode_state(cfg, b, max_len)
    t0 = time.time()
    logits, state, enc = M.prefill(params, batch, cfg, state)
    print(f"[prefill] {b} x {sp} tokens in {time.time()-t0:.2f}s")

    step = jax.jit(make_serve_step(cfg),
                   static_argnames=()) if not cfg.is_encdec else make_serve_step(cfg)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(sp + npre + i)
        tok, logits, state = step(params, state, tok, pos, enc) \
            if cfg.is_encdec else step(params, state, tok, pos)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"[decode] {args.gen-1} steps x {b} seqs in {dt:.2f}s "
          f"({(args.gen-1)*b/dt:.1f} tok/s)")
    for r in range(min(b, 2)):
        print(f"  seq{r}: {list(map(int, gen[r, :12]))}...")


if __name__ == "__main__":
    main()
