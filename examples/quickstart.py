"""Quickstart: train a ~100M-parameter LM end-to-end on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

Uses the public API only: config registry -> data pipeline -> train_step
-> checkpoint. The model is a scaled-down qwen1.5 family member (~100M
params with the full 151936-token vocab embedding).
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import ckpt  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.base import TrainConfig  # noqa: E402
from repro.data.pipeline import DataConfig, make_batch  # noqa: E402
from repro.train.steps import init_all, make_train_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart.npz")
    args = ap.parse_args()

    # ~100M params: 6 layers of d=512 + the qwen 152k vocab embedding
    cfg = dataclasses.replace(
        get_config("qwen1.5-0.5b"),
        name="qwen1.5-100m", num_layers=6, d_model=512, num_heads=8,
        num_kv_heads=8, head_dim=64, d_ff=1408, dtype="float32")
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.0f}M")

    tcfg = TrainConfig(global_batch=args.batch, micro_batch=args.batch,
                       seq_len=args.seq, steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5),
                       learning_rate=3e-4)
    params, opt = init_all(cfg)
    step = make_train_step(cfg, tcfg)
    dc = DataConfig(batch=args.batch, seq_len=args.seq)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dc, i).items()}
        params, opt, m = step(params, opt, batch)
        if i % max(args.steps // 20, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{(time.time()-t0)/(i+1):.2f}s/step")
    ckpt.save(args.ckpt, {"params": params, "opt": opt})
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
