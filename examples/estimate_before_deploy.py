"""The paper's §4 workflow, end to end: decide whether BPipe is worth
implementing for YOUR model, *before* building it — using only a cheap
single-stage measurement.

    PYTHONPATH=src python examples/estimate_before_deploy.py \
        --arch qwen1.5-32b --p 8 --t 4 --B 128

Steps (exactly the paper's recipe):
  1. memory model: find max micro-batch b under 1F1B and under BPipe;
  2. single-stage benchmark at both b (here: measured on the CPU-scale
     proxy stage; on a real cluster you'd run l/p layers on t chips);
  3. eq. 4: predicted whole-model speedup;
  4. verdict: worth it / not worth it (incl. BPipe traffic estimate).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import estimator as E  # noqa: E402
from repro.core import memory_model as MM  # noqa: E402
from repro.core import notation as N  # noqa: E402
from repro.core.flops import model_flops_train  # noqa: E402
from repro.models import model as M  # noqa: E402


def measure_stage_time(cfg, b, s, layers=2):
    """Proxy single-stage fwd+bwd wall time (CPU, reduced stage)."""
    stage = dataclasses.replace(cfg.reduced(), num_layers=layers,
                                dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), stage)
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              stage.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    f = jax.jit(jax.grad(lambda p: M.loss_fn(p, batch, stage)[0]))
    jax.block_until_ready(f(params))  # compile
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(f(params))
    return (time.perf_counter() - t0) / 3, stage


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-32b")
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--t", type=int, default=4)
    ap.add_argument("--B", type=int, default=128)
    ap.add_argument("--s", type=int, default=64, help="proxy seq len")
    ap.add_argument("--attention", default="flash",
                    choices=["none", "recompute", "flash"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    n = N.from_model(cfg, b=1, s=2048, B=args.B, p=args.p, t=args.t)

    # 1. what does memory allow? (A100-80G per the paper's cluster)
    b_1f1b = MM.max_micro_batch(n, args.attention, "1f1b", N.A100_HBM_BYTES, cfg)
    b_bpipe = MM.max_micro_batch(n, args.attention, "bpipe", N.A100_HBM_BYTES, cfg)
    print(f"[memory] {args.arch} p={args.p} t={args.t} att={args.attention}: "
          f"max b under 1F1B={b_1f1b}, under BPipe={b_bpipe}")
    if b_bpipe <= b_1f1b:
        print("[verdict] BPipe unlocks no larger micro-batch here -> skip it.")
        return

    # 2. single-stage proxy measurements at both micro-batch sizes
    t_y, stage = measure_stage_time(cfg, b_1f1b, args.s)
    t_x, _ = measure_stage_time(cfg, b_bpipe, args.s)
    fl = model_flops_train(stage, 1, args.s)
    mfu_y = b_1f1b * fl / t_y
    mfu_x = b_bpipe * fl / t_x  # relative units cancel in eq. 4
    print(f"[stage] T({b_1f1b})={t_y*1e3:.1f}ms T({b_bpipe})={t_x*1e3:.1f}ms "
          f"-> stage-MFU ratio {mfu_x/mfu_y:.3f}")

    # 3. eq. 4 + the break-even corollary
    nx = n.replace(b=b_bpipe)
    sp = E.speedup(nx, b_bpipe, b_1f1b, mfu_x, mfu_y)
    need = E.required_stage_gain(n, b_bpipe, b_1f1b)
    traffic = MM.eviction_bytes(nx, args.attention) / 2**30
    print(f"[eq.4] predicted whole-model speedup "
          f"(upper bound, BPipe overhead ignored): {sp:.3f}x")
    print(f"[break-even] stage-MFU gain required just to cover the larger "
          f"bubble: {need:.3f}x (measured {mfu_x/mfu_y:.3f}x)")
    print(f"[traffic] {traffic:.2f} GiB per evicted microbatch-stash; "
          f"1-hop on the pair-adjacent layout")

    # 4. verdict, with the paper's own caution
    if sp > 1.05:
        print(f"[verdict] >5% headroom -> BPipe likely worth implementing.")
    else:
        print("[verdict] headroom within BPipe's own overhead "
              "(the paper's LLaMA/flash case) -> NOT worth it.")


if __name__ == "__main__":
    main()
