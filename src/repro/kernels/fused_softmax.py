"""Fused scale+mask+softmax Pallas kernel (fwd + bwd).

This is the kernel whose absence the paper identified as the real source
of BPipe's GPT-3 "win" (its §3.2): at b=1 Megatron ran unfused
fp16->fp32 upcast, scale, softmax, downcast kernels; at b=2 the fused
kernel kicked in and alone delivered most of the speedup. We provide the
TPU analogue: one VMEM-resident row-tile pass. (On TPU, XLA already fuses
this chain — benchmarks/kernel_bench quantifies both paths.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _fwd_kernel(x_ref, o_ref, *, scale, causal, block_rows, sk):
    x = x_ref[...].astype(jnp.float32) * scale    # (block_rows, sk)
    if causal:
        ri = pl.program_id(0)
        rows = ri * block_rows + jax.lax.broadcasted_iota(
            jnp.int32, x.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where((rows % sk) >= cols, x, NEG_INF)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _bwd_kernel(y_ref, dy_ref, dx_ref, *, scale):
    y = y_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    dot = jnp.sum(y * dy, axis=-1, keepdims=True)
    dx_ref[...] = ((y * (dy - dot)) * scale).astype(dx_ref.dtype)


def _rows_call(kernel, x_like, n_in, block_rows, interpret, dtype=None):
    rows, sk = x_like.shape
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, sk), lambda i: (i, 0))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, sk), dtype or x_like.dtype),
        interpret=interpret)


def fused_softmax_fwd(x2d, *, scale, causal, block_rows, interpret):
    rows, sk = x2d.shape
    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               block_rows=block_rows, sk=sk)
    return _rows_call(kernel, x2d, 1, block_rows, interpret)(x2d)


def fused_softmax_bwd(y2d, dy2d, *, scale, block_rows, interpret):
    kernel = functools.partial(_bwd_kernel, scale=scale)
    return _rows_call(kernel, y2d, 2, block_rows, interpret)(y2d, dy2d)
