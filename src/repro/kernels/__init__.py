"""Pallas TPU kernels for the paper's perf-critical compute hot-spots:

  flash_attention.py — flash-attention-2 adapted to VMEM/MXU tiling
    (fwd with online softmax + LSE output, two-pass bwd, block-sparse
    skipping, GQA/window/softcap support)
  fused_softmax.py   — the §3.2 fused scale+mask+softmax chain (fwd+bwd)

ops.py = jit-ready custom_vjp wrappers; ref.py = pure-jnp oracles that
every kernel test asserts against (interpret=True on CPU).
"""
