"""Jit-ready public wrappers for the Pallas kernels.

``flash_attention``: Pallas forward + Pallas two-pass backward (dq and
dk/dv kernels recomputing probabilities from the saved LSE — the
flash-attention-2 scheme).
``fused_softmax``: Pallas forward and backward kernels via custom_vjp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_softmax as _fs
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import (flash_attention_bwd,
                                           flash_attention_fwd)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=False,
                    q_offset=0):
    """``q_offset`` shifts query positions for the causal/window masks
    (sequence-sliced attention over a retained-KV prefix of that many
    keys — docs/longcontext.md). 0 is plain full-sequence attention."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret,
        q_offset=q_offset)


def _fa_fwd(q, k, v, causal, window, softcap, scale, block_q, block_k,
            interpret, q_offset):
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret,
        return_lse=True, q_offset=q_offset)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, softcap, scale, block_q, block_k, interpret,
            q_offset, res, g):
    q, k, v, out, lse = res
    return flash_attention_bwd(
        q, k, v, out, lse, g, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, interpret=interpret,
        q_offset=q_offset)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def fused_softmax(x, scale=1.0, causal=False, block_rows=256,
                  interpret=False):
    """x: (..., sq, sk) attention scores; fused upcast+scale+mask+softmax."""
    return _fs_apply(x, scale, causal, block_rows, interpret)


def _fs_apply(x, scale, causal, block_rows, interpret):
    *lead, sq, sk = x.shape
    if causal:
        assert sq == sk, "causal fused softmax expects square scores"
    rows = 1
    for d in lead + [sq]:
        rows *= d
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    y = _fs.fused_softmax_fwd(x.reshape(rows, sk), scale=scale,
                              causal=causal, block_rows=br,
                              interpret=interpret)
    return y.reshape(x.shape)


def _fsm_fwd(x, scale, causal, block_rows, interpret):
    y = _fs_apply(x, scale, causal, block_rows, interpret)
    return y, y


def _fsm_bwd(scale, causal, block_rows, interpret, y, g):
    *lead, sq, sk = y.shape
    rows = 1
    for d in lead + [sq]:
        rows *= d
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    dx = _fs.fused_softmax_bwd(y.reshape(rows, sk), g.reshape(rows, sk),
                               scale=scale, block_rows=br,
                               interpret=interpret)
    return (dx.reshape(y.shape),)


fused_softmax.defvjp(_fsm_fwd, _fsm_bwd)


def unfused_softmax_chain(x, scale=1.0, causal=False):
    """The paper's exp-(7) *unfused* chain, staged as separate ops (upcast,
    scale, mask, softmax, downcast) — the baseline kernel_bench compares
    the fused kernel against."""
    xf = x.astype(jnp.float32)
    xf = xf * scale
    if causal:
        sq, sk = x.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        xf = jnp.where(mask, xf, _ref.NEG_INF)
    y = jax.nn.softmax(xf, axis=-1)
    return y.astype(x.dtype)
