"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None, q_offset=0):
    """q: (b, sq, nq, hd); k/v: (b, sk, nkv, hd), nq % nkv == 0.
    ``q_offset``: query row i sits at global position i + q_offset
    (sequence-sliced attention over a retained-KV prefix)."""
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    m = nq // nkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qr = q.reshape(b, sq, nkv, m, hd)
    s = jnp.einsum("bqgmh,bkgh->bgmqk", qr, k).astype(jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgmqk,bkgh->bqgmh", p, v)
    return out.reshape(b, sq, nq, hd)


def fused_softmax_ref(x, *, scale=1.0, causal=False):
    """The paper's exp-(7) kernel chain: upcast -> scale -> (mask) ->
    softmax -> downcast, as one fused op. x: (..., sq, sk)."""
    xf = x.astype(jnp.float32) * scale
    if causal:
        sq, sk = x.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        xf = jnp.where(mask, xf, NEG_INF)
    return jax.nn.softmax(xf, axis=-1).astype(x.dtype)
