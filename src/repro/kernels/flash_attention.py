"""Flash-attention (forward) as a Pallas TPU kernel.

TPU adaptation of flash-attention-2 (DESIGN.md §3): instead of warp-level
tiling in SRAM, q/k/v tiles live in VMEM via BlockSpec, the score matmul
feeds the 128x128 MXU (block sizes default to 128), and the online-softmax
running max/denominator accumulate in fp32 VMEM scratch across the
``arbitrary``-ordered kv grid dimension.

GQA: the nq//nkv query heads sharing one kv head are carried as an extra
in-tile axis m, so one kv tile is loaded once per m queries (the same
reuse flash-attention-2 gets from its head grouping).

Supports: causal masking, sliding-window (local) masking, gemma2-style
logit softcap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, block_q, block_k, nkv_blocks,
            kv_len, q_offset=0):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level sparsity (EXPERIMENTS.md §Perf HC-1 insight: masking
    # inside a dense op never saves work — skipping blocks does):
    #   causal: kv blocks strictly above the diagonal contribute nothing;
    #   window: kv blocks whose newest key is older than the oldest
    #           query's horizon contribute nothing.
    # q_offset shifts query positions by the retained-KV prefix length
    # (sequence-sliced schedules: slice queries start at global position
    # q_offset while keys cover [0, kv_len)).
    relevant = ki * block_k < kv_len
    if causal:  # oldest query in this q tile vs newest key in kv tile
        relevant &= ki * block_k <= qi * block_q + q_offset + block_q - 1
    if window:
        relevant &= (ki + 1) * block_k - 1 > qi * block_q + q_offset - window

    @pl.when(relevant)
    def _block():
        q = q_ref[0, :, 0]                     # (bq, m, hd)
        k = k_ref[0, :, 0]                     # (bk, hd)
        v = v_ref[0, :, 0]                     # (bk, hd)
        bq, m, hd = q.shape
        bk = k.shape[0]

        s = jax.lax.dot_general(
            q.reshape(bq * m, hd).astype(jnp.float32),
            k.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)     # (bq*m, bk)
        s = s.reshape(bq, m, bk) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        qpos = qi * block_q + q_offset + jax.lax.broadcasted_iota(
            jnp.int32, (bq, m, bk), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, m, bk), 2)
        mask = kpos < kv_len                    # kv padding
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                     # (bq, m)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])       # (bq, m, bk)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(bq * m, bk), v.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, m, hd)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nkv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0, :, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        lse_ref[0, :, 0] = (m_scr[...] + jnp.log(denom[..., 0])).astype(
            lse_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None, block_q=128, block_k=128,
                        interpret=False, return_lse=False, q_offset=0):
    """q: (b, sq, nq, hd); k/v: (b, sk, nkv, hd). Returns (b, sq, nq, hd).

    ``q_offset`` shifts the queries' positions for the causal/window
    masks: query row i is at global position i + q_offset while keys
    cover [0, sk) — the sequence-sliced case where the kv side carries a
    retained prefix of q_offset earlier keys (docs/longcontext.md).
    """
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    assert nq % nkv == 0
    m = nq // nkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qr = q.reshape(b, sq, nkv, m, hd)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    kp, vp = k, v
    if pad_k:
        kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    nq_blocks, nkv_blocks = sq_p // block_q, sk_p // block_k

    grid = (b, nkv, nq_blocks, nkv_blocks)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        nkv_blocks=nkv_blocks, kv_len=sk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, m, hd),
                         lambda bb, g, qi, ki: (bb, qi, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, qi, ki: (bb, ki, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, qi, ki: (bb, ki, g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, m, hd),
                         lambda bb, g, qi, ki: (bb, qi, g, 0, 0)),
            pl.BlockSpec((1, block_q, 1, m),
                         lambda bb, g, qi, ki: (bb, qi, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sq_p, nkv, m, hd), q.dtype),
            jax.ShapeDtypeStruct((b, sq_p, nkv, m), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, m), jnp.float32),
            pltpu.VMEM((block_q, m), jnp.float32),
            pltpu.VMEM((block_q, m, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qr, kp, vp)
    out, lse = out
    out = out[:, :sq].reshape(b, sq, nq, hd)
    if return_lse:
        return out, lse[:, :sq]
    return out


# ---------------------------------------------------------------------------
# Backward kernels (flash-attention-2 style two-pass)
# ---------------------------------------------------------------------------
def _recompute_p(q, k, qi, ki, *, scale, causal, window, softcap, block_q,
                 block_k, kv_len, lse, q_offset=0):
    """Recompute the (bq, m, bk) probability tile + softcap chain factor."""
    bq, m, hd = q.shape
    bk = k.shape[0]
    s = jax.lax.dot_general(
        q.reshape(bq * m, hd).astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bq, m, bk) * scale
    dcap = 1.0
    if softcap:
        t = jnp.tanh(s / softcap)
        s = softcap * t
        dcap = 1.0 - t * t           # d(softcap(s))/ds
    qpos = qi * block_q + q_offset + jax.lax.broadcasted_iota(
        jnp.int32, (bq, m, bk), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, m, bk), 2)
    mask = kpos < kv_len
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[..., None])  # masked entries -> exp(NEG_INF)=0
    return p, dcap


def _relevant(qi, ki, *, causal, window, block_q, block_k, kv_len,
              q_offset=0):
    rel = ki * block_k < kv_len
    if causal:
        rel &= ki * block_k <= qi * block_q + q_offset + block_q - 1
    if window:
        rel &= (ki + 1) * block_k - 1 > qi * block_q + q_offset - window
    return rel


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref, dq_ref,
               acc_scr, *, scale, causal, window, softcap, block_q, block_k,
               nkv_blocks, kv_len, q_offset=0):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(_relevant(qi, ki, causal=causal, window=window, block_q=block_q,
                       block_k=block_k, kv_len=kv_len, q_offset=q_offset))
    def _block():
        q = q_ref[0, :, 0]
        k = k_ref[0, :, 0]
        v = v_ref[0, :, 0]
        do = do_ref[0, :, 0].astype(jnp.float32)     # (bq, m, hd)
        lse = lse_ref[0, :, 0]
        dlt = dlt_ref[0, :, 0]                       # D = rowsum(do*o)
        bq, m, hd = q.shape
        bk = k.shape[0]
        p, dcap = _recompute_p(
            q, k, qi, ki, scale=scale, causal=causal, window=window,
            softcap=softcap, block_q=block_q, block_k=block_k,
            kv_len=kv_len, lse=lse, q_offset=q_offset)
        dp = jax.lax.dot_general(
            do.reshape(bq * m, hd), v.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, m, bk)
        ds = p * (dp - dlt[..., None]) * dcap * scale
        acc_scr[...] += jax.lax.dot_general(
            ds.reshape(bq * m, bk), k.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, m, hd)

    @pl.when(ki == nkv_blocks - 1)
    def _finish():
        dq_ref[0, :, 0] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dlt_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                softcap, block_q, block_k, nq_blocks, kv_len, q_offset=0):
    ki, qi = pl.program_id(2), pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(_relevant(qi, ki, causal=causal, window=window, block_q=block_q,
                       block_k=block_k, kv_len=kv_len, q_offset=q_offset))
    def _block():
        q = q_ref[0, :, 0]
        k = k_ref[0, :, 0]
        v = v_ref[0, :, 0]
        do = do_ref[0, :, 0].astype(jnp.float32)
        lse = lse_ref[0, :, 0]
        dlt = dlt_ref[0, :, 0]
        bq, m, hd = q.shape
        bk = k.shape[0]
        p, dcap = _recompute_p(
            q, k, qi, ki, scale=scale, causal=causal, window=window,
            softcap=softcap, block_q=block_q, block_k=block_k,
            kv_len=kv_len, lse=lse, q_offset=q_offset)
        # dv += p^T do   (sum over bq*m rows)
        dv_scr[...] += jax.lax.dot_general(
            p.reshape(bq * m, bk), do.reshape(bq * m, hd),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(
            do.reshape(bq * m, hd), v.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, m, bk)
        ds = p * (dp - dlt[..., None]) * dcap * scale
        dk_scr[...] += jax.lax.dot_general(
            ds.reshape(bq * m, bk), q.reshape(bq * m, hd).astype(jnp.float32),
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(qi == nq_blocks - 1)
    def _finish():
        dk_ref[0, :, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, dout, *, causal=True, window=0,
                        softcap=0.0, scale=None, block_q=128, block_k=128,
                        interpret=False, q_offset=0):
    """dq, dk, dv via the two-pass flash backward.

    q/dout: (b, sq, nq, hd); k/v: (b, sk, nkv, hd);
    lse: (b, sq, nkv, m) from the forward. ``q_offset`` as in the fwd.
    """
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    m = nq // nkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                            # (b, sq, nq)
    delta = delta.reshape(b, sq, nkv, m)

    qr = q.reshape(b, sq, nkv, m, hd)
    dor = dout.reshape(b, sq, nkv, m, hd)
    if pad_q:
        padq5 = ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0))
        qr = jnp.pad(qr, padq5)
        dor = jnp.pad(dor, padq5)
        lse = jnp.pad(lse, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp, vp = k, v
    if pad_k:
        kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    nq_blocks, nkv_blocks = sq_p // block_q, sk_p // block_k

    # NOTE: index maps differ between the two passes; built per pass.
    common = dict(scale=scale, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_k=block_k, kv_len=sk,
                  q_offset=q_offset)

    # --- pass 1: dq; grid (b, nkv, q_blocks, kv_blocks[arbitrary]) ----------
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, nkv_blocks=nkv_blocks, **common),
        grid=(b, nkv, nq_blocks, nkv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, m, hd),
                         lambda bb, g, qi, ki: (bb, qi, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, qi, ki: (bb, ki, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, qi, ki: (bb, ki, g, 0)),
            pl.BlockSpec((1, block_q, 1, m, hd),
                         lambda bb, g, qi, ki: (bb, qi, g, 0, 0)),
            pl.BlockSpec((1, block_q, 1, m),
                         lambda bb, g, qi, ki: (bb, qi, g, 0)),
            pl.BlockSpec((1, block_q, 1, m),
                         lambda bb, g, qi, ki: (bb, qi, g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, m, hd),
                               lambda bb, g, qi, ki: (bb, qi, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, nkv, m, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, m, hd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qr, kp, vp, dor, lse, delta)

    # --- pass 2: dk/dv; grid (b, nkv, kv_blocks, q_blocks[arbitrary]) -------
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, nq_blocks=nq_blocks, **common),
        grid=(b, nkv, nkv_blocks, nq_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, m, hd),
                         lambda bb, g, ki, qi: (bb, qi, g, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, ki, qi: (bb, ki, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, ki, qi: (bb, ki, g, 0)),
            pl.BlockSpec((1, block_q, 1, m, hd),
                         lambda bb, g, ki, qi: (bb, qi, g, 0, 0)),
            pl.BlockSpec((1, block_q, 1, m),
                         lambda bb, g, ki, qi: (bb, qi, g, 0)),
            pl.BlockSpec((1, block_q, 1, m),
                         lambda bb, g, ki, qi: (bb, qi, g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, ki, qi: (bb, ki, g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bb, g, ki, qi: (bb, ki, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sk_p, nkv, hd), k.dtype),
            jax.ShapeDtypeStruct((b, sk_p, nkv, hd), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qr, kp, vp, dor, lse, delta)

    dq = dq[:, :sq].reshape(b, sq, nq, hd)
    return dq, dk[:, :sk], dv[:, :sk]
