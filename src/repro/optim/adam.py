"""Adam with weight decay, global-norm clipping, warmup+cosine schedule.

Mixed precision per the paper's setup: master params and both moments in
fp32 (the models cast weights to bf16 at use — "cast-on-read"), gradients
arrive fp32 from the fp32 loss.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamState:
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamState:
    zeros = lambda t: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))


def lr_schedule(tcfg: TrainConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(tcfg.warmup_steps, 1))
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / max(tcfg.steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(a.astype(jnp.float32)))
                        for a in jax.tree.leaves(tree)))


def update(params, grads, state: AdamState, tcfg: TrainConfig,
           b1=0.9, b2=0.95, eps=1e-8):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gn + 1e-9)) if tcfg.grad_clip else 1.0
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)
    m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_schedule(tcfg, state.step)

    def upd(p, mu, nu):
        d = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
        if tcfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            d = d + tcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamState(step=step, m=m, v=v), {
        "grad_norm": gn, "lr": lr}
