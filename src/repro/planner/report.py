"""Human- and CSV-facing views of a ranked plan list.

The table keeps every candidate — including pruned and break-even-
rejected ones — because the *reasons* are the product: each rejection row
carries the ``required_stage_gain`` bar it failed, which is exactly what
the paper asks an engineer to check before implementing BPipe.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core import schedule as sched
from repro.core.notation import Notation
from repro.planner.rank import RankedPlan, arms_of, recommend

_COLS = ("#", "kind", "res", "v", "c", "vp", "b", "m", "cap", "d", "attn",
         "peak_GiB", "makespan_s", "MFU%", "bubble%", "stall", "eq3%",
         "req_gain", "got_gain", "moves", "verdict")


def _managed(c) -> bool:
    """Does anything manage this candidate's residency (swap policy via a
    balanced kind, or an active policy on a plain kind)?"""
    return c.kind in sched.BPIPE_FAMILY or c.residency not in ("none",)


def _cell(p: RankedPlan, col: str, idx: int) -> str:
    c = p.cand
    if col == "#":
        return str(idx)
    if col == "kind":
        return c.kind
    if col == "res":
        if c.kind in sched.BPIPE_FAMILY:
            return "swap"
        return {"none": "-", "host_offload": "offload",
                "selective_recompute": "recomp"}.get(c.residency,
                                                     c.residency)
    if col == "v":
        return str(c.v) if c.kind in sched.INTERLEAVED else "-"
    if col == "c":
        # sequence slices per microbatch (docs/longcontext.md)
        return str(c.seq_chunks) if c.seq_chunks != 1 else "-"
    if col == "vp":
        # vocab-parallel degree (docs/memory.md "Vocab accounting")
        return str(c.vocab_parallel) if c.vocab_parallel != 1 else "-"
    if col == "b":
        return str(c.b)
    if col == "m":
        return str(c.m)
    if col == "cap":
        if not _managed(c):
            return "-"
        return str(c.cap) if c.cap is not None else "def"
    if col == "d":
        # transfer-overlap depth (docs/transfer.md); only meaningful for
        # plans whose residency moves bytes over a channel
        return str(c.depth) if _managed(c) else "-"
    if col == "attn":
        return c.attention
    if col == "peak_GiB":
        return f"{p.feas.peak_gib:.3g}" if p.feas.peak_bytes else "-"
    if col == "makespan_s":
        return f"{p.makespan:.4g}" if p.makespan else "-"
    if col == "MFU%":
        return f"{100 * p.mfu:.1f}" if p.mfu else "-"
    if col == "bubble%":
        # simulated idle share (repro.obs.metrics vocabulary): what the
        # paper's eq. 2 bubble penalty actually costs this candidate
        return f"{100 * p.bubble:.1f}" if p.makespan else "-"
    if col == "stall":
        # summed backward time spent waiting on in-flight restores
        return f"{p.load_stall:.3g}" if p.makespan else "-"
    if col == "eq3%":
        return f"{100 * p.mfu_eq3:.1f}" if p.mfu_eq3 else "-"
    if col == "req_gain":
        return f"{p.required_gain:.3f}" if p.required_gain else "-"
    if col == "got_gain":
        return f"{p.achieved_gain:.3f}" if p.achieved_gain else "-"
    if col == "moves":
        return str(p.moves) if _managed(c) and p.makespan else "-"
    if col == "verdict":
        return p.verdict if not p.note else f"{p.verdict}: {p.note}"
    raise KeyError(col)


def format_table(ranked: List[RankedPlan], top: int = 0) -> str:
    """Aligned text table, best plan first (0 = all rows)."""
    rows = ranked[:top] if top else ranked
    cells = [[_cell(p, c, i + 1) for c in _COLS]
             for i, p in enumerate(rows)]
    widths = [max(len(c), *(len(r[j]) for r in cells)) if cells else len(c)
              for j, c in enumerate(_COLS)]
    def fmt(row):
        return "  ".join(s.ljust(w) for s, w in zip(row, widths)).rstrip()
    lines = [fmt(_COLS), fmt(["-" * w for w in widths])]
    lines += [fmt(r) for r in cells]
    return "\n".join(lines)


def csv_rows(ranked: List[RankedPlan], tag: str, config: str) -> List[str]:
    out = []
    for i, p in enumerate(ranked):
        c = p.cand
        out.append(
            f"{tag},{config},rank={i + 1},kind={c.kind},"
            f"res={c.residency},v={c.v},c={c.seq_chunks},"
            f"vp={c.vocab_parallel},b={c.b},"
            f"m={c.m},cap={c.cap if c.cap is not None else 'def'},"
            f"depth={c.depth},"
            f"attn={c.attention},peak_gib={p.feas.peak_gib:.2f},"
            f"mfu={100 * p.mfu:.2f},bubble={100 * p.bubble:.2f},"
            f"stall={p.load_stall:.4g},req_gain={p.required_gain:.3f},"
            f"got_gain={p.achieved_gain:.3f},moves={p.moves},"
            f"traffic_gib={p.traffic_bytes / 2**30:.2f},"
            f"verdict={p.verdict}")
    return out


def recommendation_line(config: str, ranked: List[RankedPlan],
                        attention: Optional[str] = None) -> str:
    """One line per the acceptance contract: the winning plan, or why
    nothing fits; BPipe rejections cite the break-even number."""
    arm = f" [{attention}]" if attention else ""
    best = recommend(ranked, attention)
    if best is None:
        return f"PLAN {config}{arm}: no feasible plan under this HBM budget"
    c = best.cand
    bits = [c.kind, f"b={c.b}", f"m={c.m}"]
    if c.kind in sched.INTERLEAVED:
        bits.append(f"v={c.v}")
    if c.seq_chunks != 1:
        bits.append(f"c={c.seq_chunks}")
    if c.residency not in ("none", "bpipe_swap"):
        bits.append(f"res={c.residency}")
    if _managed(c):
        bits.append(f"cap={c.cap if c.cap is not None else 'default'}")
    if c.depth != 1:
        bits.append(f"depth={c.depth}")
    if c.vocab_parallel != 1:
        bits.append(f"vp={c.vocab_parallel}")
    if attention is None:
        bits.append(c.attention)
    why = f"est {100 * best.mfu:.1f}% MFU"
    if c.kind in sched.BPIPE_FAMILY and best.required_gain:
        why += (f"; break-even needed {best.required_gain:.3f}x stage gain, "
                f"calibration gives {best.achieved_gain:.3f}x")
    else:
        rej = [p for p in ranked
               if p.verdict == "reject"
               and (attention is None or p.cand.attention == attention)]
        if rej:
            # Cite the paper's story: BPipe's pitch is unlocking a LARGER
            # micro batch, so quote the best rejected plan that actually
            # raised b over the baseline (fall back to the best reject).
            raised = [p for p in rej if p.cand.b > p.baseline_b]
            r = max(raised or rej, key=lambda p: p.mfu)
            why += (f"; BPipe rejected at b={r.cand.b}: required "
                    f"{r.required_gain:.3f}x stage gain, got "
                    f"{r.achieved_gain:.3f}x")
    return f"PLAN {config}{arm}: {' '.join(bits)} — {why}"


def summarize(config: str, n: Notation,
              ranked: List[RankedPlan]) -> List[str]:
    """Per-attention-arm recommendations plus the overall pick."""
    lines = [recommendation_line(config, ranked, att)
             for att in arms_of(ranked)]
    lines.append(recommendation_line(config, ranked))
    return lines
