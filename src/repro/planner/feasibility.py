"""Memory feasibility pruning: does a candidate plan fit the HBM budget?

Consumes ``core.memory_model``'s per-stage peak accounting — stash-unit
counts from the actual schedule streams (cap-, v-chunk- and
residency-aware: units a policy spills off the device are charged only
their retained bytes) plus param/optimizer state — and ``core.bpipe``'s
pair layout for the per-pair hop cost the ranking stage charges eviction
traffic with (the device-ring-extent hop distances, not the p-sized
default).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import bpipe as BP
from repro.core import memory_model as mm
from repro.core import schedule as sched
from repro.core.notation import Notation
from repro.planner.space import Candidate

DEFAULT_WORKSPACE = 4 * 1024**3


@dataclasses.dataclass(frozen=True)
class Feasibility:
    ok: bool
    reason: str = ""            # "" when ok
    peak_bytes: float = 0.0     # max per-stage peak (act + params)
    pair_hops: int = 0          # max evictor<->acceptor ring hops

    @property
    def peak_gib(self) -> float:
        return self.peak_bytes / 2**30


def check(n: Notation, cand: Candidate, hbm_bytes: float,
          cfg: Optional[ModelConfig] = None,
          workspace: float = DEFAULT_WORKSPACE,
          stage_to_device: Optional[Tuple[int, ...]] = None) -> Feasibility:
    """Prune ``cand`` against the per-device HBM budget.

    ``stage_to_device`` overrides the pair-adjacent layout when the
    stages sit on a larger mesh axis; the resulting (corrected) hop
    distance feeds the ranking stage's eviction cost.
    """
    p = n.p
    if n.B % cand.b or cand.m != n.B // cand.b:
        return Feasibility(False, f"b={cand.b} does not tile B={n.B}")
    nb = n.replace(b=cand.b)
    if cand.kind in sched.INTERLEAVED:
        if cand.v < 2:
            return Feasibility(False, "interleaved needs v >= 2")
        if cand.m % p:
            return Feasibility(False, f"m={cand.m} % p={p} != 0")
    if cfg is not None and p * cand.v > cfg.num_layers:
        return Feasibility(False, f"p*v={p * cand.v} > {cfg.num_layers} layers")

    try:
        spec = cand.spec(p)
        # template=True: peak accounting saturates in m, so a large-m
        # candidate is priced off its small saturation template
        # (plan.peak_template_spec) — identical peaks, fraction of the
        # compile cost. Exception behavior (cap unbalanceable) is
        # m-independent past saturation too (property-pinned).
        peak = mm.max_stage_bytes(nb, cand.attention, spec, cfg,
                                  template=True)
    except (AssertionError, IndexError, ValueError):
        # _balance cannot hold the stream under this cap (too tight for
        # the in-flight transients at this (p, m, v)).
        return Feasibility(False, f"cap={cand.cap} unbalanceable")

    hops = 0
    if spec.balanced:
        plan = BP.plan(p, cand.m, stage_to_device, spec=spec)
        hops = max(BP.hop_distance(plan).values(), default=0)
    if peak + workspace > hbm_bytes:
        return Feasibility(
            False,
            f"OOM: {peak / 2**30:.1f} GiB + workspace > "
            f"{hbm_bytes / 2**30:.0f} GiB",
            peak_bytes=peak, pair_hops=hops)
    return Feasibility(True, peak_bytes=peak, pair_hops=hops)
