"""``repro.planner``: the schedule auto-planner.

Searches the (kind, residency, v, b, m, cap, attention) space for one
training config, prunes with the analytical memory model, ranks
survivors with the discrete-event simulator plus the paper's §4
break-even test, and calibrates costs from real executor traces. See
docs/planner.md and docs/memory.md (the residency dimension).

    from repro.planner import plan_config
    ranked = plan_config(notation, cfg, hbm_bytes=80 * 2**30)

CLI front door: ``python -m repro.launch.plan --config llama_65b``.
"""
from __future__ import annotations

from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core.notation import NVLINK_BW, Notation
from repro.planner import calibrate, feasibility, rank, report, space
from repro.planner.rank import (AnalyticCostModel, CostModel, RankedPlan,
                                Table5CostModel, recommend)
from repro.planner.space import Candidate, SearchSpace

__all__ = [
    "AnalyticCostModel", "Candidate", "CostModel", "RankedPlan",
    "SearchSpace", "Table5CostModel", "calibrate", "cost_model_for",
    "feasibility", "plan_config", "rank", "recommend", "report", "space",
]

# Configs the paper measured (Table 5) — these get the calibrated curves.
PAPER_MODELS = ("gpt3-96b", "llama-65b")


def cost_model_for(cfg: Optional[ModelConfig],
                   peak_per_chip: Optional[float] = None) -> CostModel:
    """Table5-calibrated for the paper's models, analytic otherwise."""
    kw = {} if peak_per_chip is None else {"peak_per_chip": peak_per_chip}
    if cfg is not None and cfg.name in PAPER_MODELS:
        return Table5CostModel(cfg.name, **kw)
    return AnalyticCostModel(cfg, **kw)


def plan_config(n: Notation, cfg: Optional[ModelConfig], hbm_bytes: float,
                cost: Optional[CostModel] = None,
                search: SearchSpace = SearchSpace(),
                link_bw: float = NVLINK_BW,
                overhead: float = 0.0,
                workspace: float = feasibility.DEFAULT_WORKSPACE,
                host_bw: Optional[float] = None,
                exhaustive: bool = False,
                ) -> List[RankedPlan]:
    """End-to-end: enumerate -> prune -> rank for one config.
    ``host_bw`` (bytes/s) prices host_offload residency; None = PCIe.
    ``exhaustive=True`` disables the branch-and-bound pruning and
    simulates every feasible candidate (same recommendation, slower —
    the escape hatch and the differential-test oracle)."""
    if cost is None:
        cost = cost_model_for(cfg)
    cands = space.enumerate_candidates(
        n, search, cfg.num_layers if cfg is not None else 0)
    kw = {} if host_bw is None else {"host_bw": host_bw}
    return rank.rank(n, cands, cost, hbm_bytes, cfg, link_bw=link_bw,
                     overhead=overhead, workspace=workspace,
                     exhaustive=exhaustive, **kw)
