"""Rank feasible plans: discrete-event-simulated makespan/MFU plus the
paper's §4 break-even test.

The cost side is pluggable (``CostModel``): per-microbatch single-stage
time T(b) is all the simulator needs, and all the break-even test needs
is the *ratio* b_x/T(b_x) : b_y/T(b_y) — the paper's "two cheap
single-stage measurements" (§4). Three sources are provided:

  * ``Table5CostModel`` — the paper's measured single-stage MFUs
    (Table 5), interpolated with ``estimator.fit_stage_mfu``; this is the
    model that reproduces the paper's Table 3 verdicts from first
    principles (BPipe wins GPT-3-recompute, loses LLaMA and flash).
  * ``AnalyticCostModel`` — a saturating-efficiency roofline guess for
    configs nobody has measured yet.
  * ``planner.calibrate.TraceCostModel`` — fit from a real executor
    event trace.

A BPipe-family plan is *rejected* (kept in the table, excluded from the
recommendation) when its stage gain over the best feasible plain-1F1B
baseline falls short of ``estimator.required_stage_gain`` — the paper's
decision procedure, applied automatically per attention arm.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import estimator as E
from repro.core import memory_model as mm
from repro.core import plan as plan_mod
from repro.core import schedule as sched
from repro.core import simulator as SIM
from repro.core.flops import model_flops_train, paper_flops
from repro.core.notation import A100_PEAK_BF16, NVLINK_BW, PCIE_BW, Notation
from repro.planner import feasibility
from repro.planner.space import ATTENTION_ARMS, Candidate


# ---------------------------------------------------------------------------
# Cost models
# ---------------------------------------------------------------------------
class CostModel:
    """Single-stage cost oracle: T(b) seconds of fwd+bwd per microbatch."""

    peak_per_chip: float = A100_PEAK_BF16

    def full_flops(self, n: Notation) -> float:
        """fwd+bwd FLOPs of the whole model over the global batch."""
        return paper_flops(n.replace(b=n.B))

    def stage_T(self, n: Notation, attention: str) -> float:
        raise NotImplementedError

    def mfu_stage(self, n: Notation, attention: str) -> float:
        """Single-stage MFU implied by stage_T (fraction, not percent)."""
        Fs = self.full_flops(n) / n.p
        return (n.b / n.B) * Fs / (self.peak_per_chip * n.t
                                   * self.stage_T(n, attention))

    def stage_gain(self, n: Notation, bx: int, by: int,
                   attention: str) -> float:
        """MFU_stage(bx)/MFU_stage(by) — what eq. 4 weighs against the
        bubble penalty. Equals (bx/T(bx)) / (by/T(by))."""
        Tx = self.stage_T(n.replace(b=bx), attention)
        Ty = self.stage_T(n.replace(b=by), attention)
        return (bx / Tx) / (by / Ty)


class Table5CostModel(CostModel):
    """Trace-calibrated to the paper's own measurements: single-stage MFU
    points from Table 5, one saturating curve per attention arm."""

    def __init__(self, model: str, peak_per_chip: float = A100_PEAK_BF16):
        rows = [r for r in E.PAPER_ROWS if r.model == model]
        assert rows, f"no Table 5 rows for {model!r}"
        self.model = model
        self.peak_per_chip = peak_per_chip
        self._curves = {}
        for att in sorted({r.attention for r in rows}):
            pts = {r.b: r.mfu_stage / 100.0
                   for r in rows if r.attention == att}
            self._curves[att] = E.fit_stage_mfu(pts)

    def _curve(self, attention: str):
        if attention in self._curves:
            return self._curves[attention]
        # Unmeasured arm: borrow in a FIXED preference order — flash
        # first ("none" and flash both skip the recompute re-forward, so
        # their compute time is closest; they differ only in memory),
        # then recompute. Iteration-order fallbacks here made planner
        # output depend on PYTHONHASHSEED.
        for fb in ("flash", "recompute", "none"):
            if fb in self._curves:
                return self._curves[fb]
        raise KeyError(attention)  # unreachable: rows is non-empty

    def stage_T(self, n: Notation, attention: str) -> float:
        mfu = self._curve(attention)(n.b)
        Fs = self.full_flops(n) / n.p
        return E.stage_T_from_mfu(n, Fs, mfu, self.peak_per_chip * n.t)


class AnalyticCostModel(CostModel):
    """Roofline-flavored guess for unmeasured configs: efficiency
    saturates as eff(b) = eff_max * b / (b + b_half), and the attention
    arm scales time (recompute redoes attention forward in the backward;
    flash skips the score materialization round-trips). Constants are
    rough A100 shapes — the point is relative ranking, and the defaults
    deliberately put the 1-vs-2 microbatch stage gain near the paper's
    measured ~1.1x ridge so break-even verdicts stay conservative."""

    TIME_FACTOR = {"none": 1.0, "recompute": 1.12, "flash": 0.95}

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 peak_per_chip: float = A100_PEAK_BF16,
                 eff_max: float = 0.62, b_half: float = 0.35):
        self.cfg = cfg
        self.peak_per_chip = peak_per_chip
        self.eff_max, self.b_half = eff_max, b_half

    def full_flops(self, n: Notation) -> float:
        if self.cfg is not None:
            return model_flops_train(self.cfg, n.B, n.s)
        return paper_flops(n.replace(b=n.B))

    def stage_T(self, n: Notation, attention: str) -> float:
        eff = self.eff_max * n.b / (n.b + self.b_half)
        share = (n.b / n.B) * self.full_flops(n) / n.p
        return (share / (self.peak_per_chip * n.t * eff)
                * self.TIME_FACTOR[attention])


# ---------------------------------------------------------------------------
# Ranking
# ---------------------------------------------------------------------------
def _bubble_term(n: Notation, b: int, kind: str, v: int) -> float:
    """B + b * (ramp flush units): the denominator of eq. 3's bubble
    penalty, generalized to interleaved kinds whose ramp shrinks to
    (p-1)/v (see ``simulator.interleaved_ideal_makespan``)."""
    ramp = (n.p - 1) / v if kind in sched.INTERLEAVED else (n.p - 1)
    return n.B + b * ramp


def _required_gain(n: Notation, cand: Candidate, base: Candidate,
                   overhead: float) -> float:
    """Break-even stage gain for ``cand`` vs the 1F1B ``base``. For plain
    BPipe this is exactly ``estimator.required_stage_gain``; interleaved
    candidates get their own (v-fold smaller) bubble penalty — using the
    plain formula there over-rejects plans whose simulated makespan beats
    the baseline."""
    if cand.kind not in sched.INTERLEAVED:
        return E.required_stage_gain(n, cand.b, base.b, overhead)
    return (_bubble_term(n, cand.b, cand.kind, cand.v)
            / _bubble_term(n, base.b, base.kind, 1)) * (1.0 + overhead)


def sim_config_for(n: Notation, rp: "RankedPlan", cost: CostModel,
                   link_bw: float = NVLINK_BW,
                   host_bw: Optional[float] = None) -> SIM.SimConfig:
    """The exact ``SimConfig`` ``rank`` prices a candidate with —
    exposed so the CLI can re-simulate a recommended plan with an
    observer attached (Perfetto export, metrics JSON) without
    re-deriving any knob."""
    cand = rp.cand
    nb = n.replace(b=cand.b)
    T = cost.stage_T(nb, cand.attention)
    spec = cand.spec(n.p)
    hb = host_bw if host_bw is not None else PCIE_BW
    return SIM.SimConfig(
        spec=spec, Tf=T / 3.0, Tb=2.0 * T / 3.0,
        evict_bytes=(mm.eviction_bytes(nb, cand.attention, spec.v,
                                       spec.seq_chunks)
                     if spec.policy.moves_data else 0.0),
        pair_bw=link_bw, pair_hops=max(rp.feas.pair_hops, 1),
        d2h_bw=hb, h2d_bw=hb)


@dataclasses.dataclass
class RankedPlan:
    cand: Candidate
    feas: feasibility.Feasibility
    stage_T: float = 0.0
    makespan: float = 0.0
    bubble: float = 0.0         # simulated bubble fraction (idle share)
    load_stall: float = 0.0
    move_time: float = 0.0      # summed residency-op time (tie-breaker)
    mfu: float = 0.0            # simulator-derived (fraction)
    mfu_eq3: float = 0.0        # eq. 3 closed form (fraction)
    required_gain: float = 0.0  # break-even vs the arm's 1F1B baseline
    achieved_gain: float = 0.0
    baseline_b: int = 0
    moves: int = 0              # EVICT+LOAD count of the stream built
    traffic_bytes: float = 0.0  # moves x per-unit stash bytes
    verdict: str = ""           # "ok" | "reject" | "infeasible"
    note: str = ""

    @property
    def ok(self) -> bool:
        return self.verdict == "ok"


def rank(n: Notation, cands: Iterable[Candidate], cost: CostModel,
         hbm_bytes: float, cfg: Optional[ModelConfig] = None,
         link_bw: float = NVLINK_BW,
         workspace: float = feasibility.DEFAULT_WORKSPACE,
         stage_to_device: Optional[Tuple[int, ...]] = None,
         overhead: float = 0.0,
         host_bw: float = PCIE_BW) -> List[RankedPlan]:
    """Feasibility-prune, simulate, break-even-test and sort candidates.

    ``overhead`` inflates the break-even bar by a fractional BPipe cost
    (``estimator.required_stage_gain``'s knob); 0.0 mirrors the paper's
    "temporarily ignore the overhead" idealization — the simulator still
    charges the traffic it can see. ``host_bw`` prices host_offload's
    D2H/H2D copies (PCIe-class by default — the bandwidth asymmetry vs.
    ``link_bw`` is exactly what the residency contest is about);
    selective_recompute is FLOPs-costed by the simulator's RECOMPUTE
    handler instead.
    """
    plans: List[RankedPlan] = []
    for cand in cands:
        feas = feasibility.check(n, cand, hbm_bytes, cfg, workspace,
                                 stage_to_device)
        rp = RankedPlan(cand=cand, feas=feas)
        if not feas.ok:
            rp.verdict, rp.note = "infeasible", feas.reason
            plans.append(rp)
            continue
        nb = n.replace(b=cand.b)
        spec = cand.spec(n.p)
        simcfg = sim_config_for(n, rp, cost, link_bw, host_bw)
        T = simcfg.Tf + simcfg.Tb
        res = SIM.simulate(simcfg)
        F = cost.full_flops(n)
        rp.stage_T = T
        rp.makespan = res.makespan
        rp.bubble = res.bubble_fraction
        rp.load_stall = res.load_stall
        rp.move_time = res.move_time
        # Traffic accounting from the stream actually built (cap- and
        # v-aware), not a default-cap closed form.
        rp.moves = plan_mod.num_moves(spec)
        rp.traffic_bytes = mm.traffic_bytes(nb, cand.attention, spec)
        rp.mfu = SIM.mfu_from_sim(res, F, n.p, n.t, cost.peak_per_chip)
        rp.mfu_eq3 = E.mfu_model(nb, F, F / n.p,
                                 cost.mfu_stage(nb, cand.attention))
        rp.verdict = "ok"
        plans.append(rp)

    # §4 break-even pass, per attention arm, against the best feasible
    # UNMANAGED plain-1F1B plan (the paper's baseline schedule — a
    # residency-managed 1f1b is a contender, not the baseline). Every
    # residency-managed plan faces the same bar: its whole point is
    # unlocking a larger micro batch, so it must deliver the stage gain
    # eq. 4 demands, whichever mechanism pays for the memory.
    for att in {p.cand.attention for p in plans}:
        arm = [p for p in plans if p.cand.attention == att]
        base_cands = [p for p in arm if p.cand.kind == "1f1b"
                      and p.cand.residency == "none"]
        base = max((p for p in base_cands if p.ok),
                   key=lambda p: p.mfu, default=None)
        for p in arm:
            c = p.cand
            managed = (c.kind in sched.BPIPE_FAMILY
                       or c.residency not in ("none",))
            if not p.ok or not managed:
                continue
            if base is None:
                # distinguish "nothing unmanaged fits" (residency
                # genuinely enables the arm) from "the caller excluded
                # the baseline from the search" — only the former is a
                # claim about memory
                p.note = ("no feasible 1f1b baseline "
                          "(residency enables the arm)" if base_cands
                          else "unmanaged 1f1b baseline not searched "
                               "(break-even untested)")
                continue
            req = _required_gain(n, c, base.cand, overhead)
            got = cost.stage_gain(n, c.b, base.cand.b, att)
            p.required_gain, p.achieved_gain = req, got
            p.baseline_b = base.cand.b
            if got + 1e-12 < req:
                p.verdict = "reject"
                p.note = (f"break-even: needs >={req:.3f}x stage gain over "
                          f"1f1b b={base.cand.b}, got {got:.3f}x")

    order = {"ok": 0, "reject": 1, "infeasible": 2}
    # move_time breaks equal-MFU ties: at the same simulated throughput,
    # prefer the plan with the least residency traffic in flight (less
    # exposure to link contention the model cannot see).
    plans.sort(key=lambda p: (order[p.verdict], -p.mfu, p.move_time))
    return plans


def recommend(ranked: List[RankedPlan],
              attention: Optional[str] = None) -> Optional[RankedPlan]:
    """The plan the planner stands behind: best simulated MFU among
    feasible plans that survived the break-even test."""
    for p in ranked:
        if p.ok and (attention is None or p.cand.attention == attention):
            return p
    return None


def arms_of(ranked: List[RankedPlan]) -> List[str]:
    seen = [a for a in ATTENTION_ARMS
            if any(p.cand.attention == a for p in ranked)]
    return seen
