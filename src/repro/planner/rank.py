"""Rank feasible plans: discrete-event-simulated makespan/MFU plus the
paper's §4 break-even test.

The cost side is pluggable (``CostModel``): per-microbatch single-stage
time T(b) is all the simulator needs, and all the break-even test needs
is the *ratio* b_x/T(b_x) : b_y/T(b_y) — the paper's "two cheap
single-stage measurements" (§4). Three sources are provided:

  * ``Table5CostModel`` — the paper's measured single-stage MFUs
    (Table 5), interpolated with ``estimator.fit_stage_mfu``; this is the
    model that reproduces the paper's Table 3 verdicts from first
    principles (BPipe wins GPT-3-recompute, loses LLaMA and flash).
  * ``AnalyticCostModel`` — a saturating-efficiency roofline guess for
    configs nobody has measured yet.
  * ``planner.calibrate.TraceCostModel`` — fit from a real executor
    event trace.

A BPipe-family plan is *rejected* (kept in the table, excluded from the
recommendation) when its stage gain over the best feasible plain-1F1B
baseline falls short of ``estimator.required_stage_gain`` — the paper's
decision procedure, applied automatically per attention arm.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import estimator as E
from repro.core import memory_model as mm
from repro.core import plan as plan_mod
from repro.core import schedule as sched
from repro.core import simulator as SIM
from repro.core.flops import model_flops_train, paper_flops
from repro.core.notation import A100_PEAK_BF16, NVLINK_BW, PCIE_BW, Notation
from repro.planner import feasibility
from repro.planner.space import ATTENTION_ARMS, Candidate


# ---------------------------------------------------------------------------
# Cost models
# ---------------------------------------------------------------------------
class CostModel:
    """Single-stage cost oracle: T(b) seconds of fwd+bwd per microbatch."""

    peak_per_chip: float = A100_PEAK_BF16

    def full_flops(self, n: Notation) -> float:
        """fwd+bwd FLOPs of the whole model over the global batch."""
        return paper_flops(n.replace(b=n.B))

    def stage_T(self, n: Notation, attention: str) -> float:
        raise NotImplementedError

    def mfu_stage(self, n: Notation, attention: str) -> float:
        """Single-stage MFU implied by stage_T (fraction, not percent)."""
        Fs = self.full_flops(n) / n.p
        return (n.b / n.B) * Fs / (self.peak_per_chip * n.t
                                   * self.stage_T(n, attention))

    def stage_gain(self, n: Notation, bx: int, by: int,
                   attention: str) -> float:
        """MFU_stage(bx)/MFU_stage(by) — what eq. 4 weighs against the
        bubble penalty. Equals (bx/T(bx)) / (by/T(by))."""
        Tx = self.stage_T(n.replace(b=bx), attention)
        Ty = self.stage_T(n.replace(b=by), attention)
        return (bx / Tx) / (by / Ty)


class Table5CostModel(CostModel):
    """Trace-calibrated to the paper's own measurements: single-stage MFU
    points from Table 5, one saturating curve per attention arm."""

    def __init__(self, model: str, peak_per_chip: float = A100_PEAK_BF16):
        rows = [r for r in E.PAPER_ROWS if r.model == model]
        assert rows, f"no Table 5 rows for {model!r}"
        self.model = model
        self.peak_per_chip = peak_per_chip
        self._curves = {}
        for att in sorted({r.attention for r in rows}):
            pts = {r.b: r.mfu_stage / 100.0
                   for r in rows if r.attention == att}
            self._curves[att] = E.fit_stage_mfu(pts)

    def _curve(self, attention: str):
        if attention in self._curves:
            return self._curves[attention]
        # Unmeasured arm: borrow in a FIXED preference order — flash
        # first ("none" and flash both skip the recompute re-forward, so
        # their compute time is closest; they differ only in memory),
        # then recompute. Iteration-order fallbacks here made planner
        # output depend on PYTHONHASHSEED.
        for fb in ("flash", "recompute", "none"):
            if fb in self._curves:
                return self._curves[fb]
        raise KeyError(attention)  # unreachable: rows is non-empty

    def stage_T(self, n: Notation, attention: str) -> float:
        mfu = self._curve(attention)(n.b)
        Fs = self.full_flops(n) / n.p
        return E.stage_T_from_mfu(n, Fs, mfu, self.peak_per_chip * n.t)


class AnalyticCostModel(CostModel):
    """Roofline-flavored guess for unmeasured configs: efficiency
    saturates as eff(b) = eff_max * b / (b + b_half), and the attention
    arm scales time (recompute redoes attention forward in the backward;
    flash skips the score materialization round-trips). Constants are
    rough A100 shapes — the point is relative ranking, and the defaults
    deliberately put the 1-vs-2 microbatch stage gain near the paper's
    measured ~1.1x ridge so break-even verdicts stay conservative."""

    TIME_FACTOR = {"none": 1.0, "recompute": 1.12, "flash": 0.95}

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 peak_per_chip: float = A100_PEAK_BF16,
                 eff_max: float = 0.62, b_half: float = 0.35):
        self.cfg = cfg
        self.peak_per_chip = peak_per_chip
        self.eff_max, self.b_half = eff_max, b_half

    def full_flops(self, n: Notation) -> float:
        if self.cfg is not None:
            return model_flops_train(self.cfg, n.B, n.s)
        return paper_flops(n.replace(b=n.B))

    def stage_T(self, n: Notation, attention: str) -> float:
        eff = self.eff_max * n.b / (n.b + self.b_half)
        share = (n.b / n.B) * self.full_flops(n) / n.p
        return (share / (self.peak_per_chip * n.t * eff)
                * self.TIME_FACTOR[attention])


# ---------------------------------------------------------------------------
# Ranking
# ---------------------------------------------------------------------------
def _bubble_term(n: Notation, b: int, kind: str, v: int) -> float:
    """B + b * (ramp flush units): the denominator of eq. 3's bubble
    penalty, generalized to interleaved kinds whose ramp shrinks to
    (p-1)/v (see ``simulator.interleaved_ideal_makespan``)."""
    ramp = (n.p - 1) / v if kind in sched.INTERLEAVED else (n.p - 1)
    return n.B + b * ramp


def _required_gain(n: Notation, cand: Candidate, base: Candidate,
                   overhead: float) -> float:
    """Break-even stage gain for ``cand`` vs the 1F1B ``base``. For plain
    BPipe this is exactly ``estimator.required_stage_gain``; interleaved
    candidates get their own (v-fold smaller) bubble penalty — using the
    plain formula there over-rejects plans whose simulated makespan beats
    the baseline."""
    if cand.kind not in sched.INTERLEAVED:
        return E.required_stage_gain(n, cand.b, base.b, overhead)
    return (_bubble_term(n, cand.b, cand.kind, cand.v)
            / _bubble_term(n, base.b, base.kind, 1)) * (1.0 + overhead)


def sim_config_for(n: Notation, rp: "RankedPlan", cost: CostModel,
                   link_bw: float = NVLINK_BW,
                   host_bw: Optional[float] = None) -> SIM.SimConfig:
    """The exact ``SimConfig`` ``rank`` prices a candidate with —
    exposed so the CLI can re-simulate a recommended plan with an
    observer attached (Perfetto export, metrics JSON) without
    re-deriving any knob."""
    cand = rp.cand
    nb = n.replace(b=cand.b)
    T = cost.stage_T(nb, cand.attention)
    spec = cand.spec(n.p)
    hb = host_bw if host_bw is not None else PCIE_BW
    return SIM.SimConfig(
        spec=spec, Tf=T / 3.0, Tb=2.0 * T / 3.0,
        evict_bytes=(mm.eviction_bytes(nb, cand.attention, spec.v,
                                       spec.seq_chunks)
                     if spec.policy.moves_data else 0.0),
        pair_bw=link_bw, pair_hops=max(rp.feas.pair_hops, 1),
        d2h_bw=hb, h2d_bw=hb,
        t_vocab=(mm.vocab_collective_bytes(nb, spec.vocab_parallel)
                 / link_bw))


@dataclasses.dataclass
class RankedPlan:
    cand: Candidate
    feas: feasibility.Feasibility
    stage_T: float = 0.0
    makespan: float = 0.0
    bubble: float = 0.0         # simulated bubble fraction (idle share)
    load_stall: float = 0.0
    move_time: float = 0.0      # summed residency-op time (tie-breaker)
    mfu: float = 0.0            # simulator-derived (fraction)
    mfu_eq3: float = 0.0        # eq. 3 closed form (fraction)
    required_gain: float = 0.0  # break-even vs the arm's 1F1B baseline
    achieved_gain: float = 0.0
    baseline_b: int = 0
    moves: int = 0              # EVICT+LOAD count of the stream built
    traffic_bytes: float = 0.0  # moves x per-unit stash bytes
    mfu_bound: float = 0.0      # admissible MFU upper bound (B&B pricing)
    verdict: str = ""           # "ok" | "reject" | "pruned" | "infeasible"
    note: str = ""

    @property
    def ok(self) -> bool:
        return self.verdict == "ok"


#: Sort order of verdicts in the ranked table. "pruned" rows (candidates
#: the branch-and-bound search discarded without simulating: bound below
#: the incumbent, dominated depth twins, or break-even rejects at
#: b <= baseline) sit between the simulated rejects and the infeasible.
VERDICT_ORDER = {"ok": 0, "reject": 1, "pruned": 2, "infeasible": 3}

#: Pruning margin on MFU fractions: a candidate is discarded only when
#: its admissible bound is below the incumbent by more than this — keeps
#: float noise in the makespan summation from ever pruning an exact tie
#: (ties MUST be simulated for the stable tie-break to match exhaustive
#: search).
PRUNE_MARGIN = 1e-9


def mfu_upper_bound(n: Notation, cand: Candidate, cost: CostModel,
                    link_bw: float = NVLINK_BW) -> float:
    """Admissible MFU upper bound for ``cand`` priced from the cost model
    alone (no compile, no simulation): the kind-appropriate ideal
    makespan — ``(m + ramp) * T`` with the plain (p-1), interleaved
    (p-1)/v, or sliced (p-1)/c ramp (``simulator.ideal_makespan``
    family) — converted to MFU. A vocab-parallel candidate additionally
    serializes one collective onto each boundary-stage F and B, so its
    makespan floor gains ``2 m t_vocab`` (the boundary stage alone must
    run m microbatches, each inflated by two collectives, after the
    ramp). The simulator can only ADD time to the ideal (hops, stalls,
    recompute, warmup skew), so simulated MFU never exceeds this bound;
    a candidate whose bound cannot beat the incumbent best MFU cannot be
    the recommendation."""
    nb = n.replace(b=cand.b)
    T = cost.stage_T(nb, cand.attention)
    entry = sched.SCHEDULES[cand.kind]
    if entry.interleaved:
        ramp = (n.p - 1) / cand.v
    elif entry.sliced and cand.seq_chunks > 1:
        ramp = (n.p - 1) / cand.seq_chunks
    else:
        ramp = n.p - 1
    lb = (cand.m + ramp) * T
    if cand.vocab_parallel > 1:
        t_vocab = mm.vocab_collective_bytes(nb, cand.vocab_parallel) / link_bw
        lb += cand.m * 2.0 * t_vocab
    return cost.full_flops(n) / (lb * n.p * n.t * cost.peak_per_chip)


def _move_floor(n: Notation, rp: "RankedPlan", cost: CostModel,
                link_bw: float, host_bw: float) -> float:
    """Makespan floor from mandatory residency traffic: the busiest
    per-stage channel must fit its moves' serialized busy time inside the
    makespan (every release completes before its restore issues, every
    restore before its backward — all inside [0, makespan], and a channel
    runs FIFO). Move counts come from the candidate's saturation template
    (``plan.peak_template_spec`` — already compiled by feasibility), which
    never over-counts: per-stage counts are monotone nondecreasing in m
    past saturation (property-pinned). 0.0 when the policy moves no
    bytes."""
    cand = rp.cand
    spec = cand.spec(n.p)
    if not spec.policy.moves_data:
        return 0.0
    nb = n.replace(b=cand.b)
    sch = plan_mod.compile_plan(plan_mod.peak_template_spec(spec))
    unit = mm.eviction_bytes(nb, cand.attention, spec.v, spec.seq_chunks)
    if spec.policy.mechanism == "swap":
        t_rel = t_res = (unit / link_bw) * max(rp.feas.pair_hops, 1)
    else:
        t_rel = t_res = unit / host_bw
    return max((max(sch.num_evictions.get(i, 0) * t_rel,
                    sch.num_loads.get(i, 0) * t_res)
                for i in range(n.p)), default=0.0)


def _price(rp: "RankedPlan", n: Notation, cost: CostModel,
           link_bw: float, host_bw: float) -> None:
    """Simulate a feasible candidate and fill its metrics (verdict
    "ok" — the break-even pass may downgrade it afterwards)."""
    cand = rp.cand
    nb = n.replace(b=cand.b)
    spec = cand.spec(n.p)
    simcfg = sim_config_for(n, rp, cost, link_bw, host_bw)
    T = simcfg.Tf + simcfg.Tb
    res = SIM.simulate(simcfg)
    F = cost.full_flops(n)
    rp.stage_T = T
    rp.makespan = res.makespan
    rp.bubble = res.bubble_fraction
    rp.load_stall = res.load_stall
    rp.move_time = res.move_time
    # Traffic accounting from the stream actually built (cap- and
    # v-aware), not a default-cap closed form.
    rp.moves = plan_mod.num_moves(spec)
    rp.traffic_bytes = mm.traffic_bytes(nb, cand.attention, spec)
    rp.mfu = SIM.mfu_from_sim(res, F, n.p, n.t, cost.peak_per_chip)
    rp.mfu_eq3 = E.mfu_model(nb, F, F / n.p,
                             cost.mfu_stage(nb, cand.attention))
    rp.verdict = "ok"


def _check_feas(rp: "RankedPlan", n: Notation, hbm_bytes: float,
                cfg: Optional[ModelConfig], workspace: float,
                stage_to_device: Optional[Tuple[int, ...]]) -> bool:
    rp.feas = feasibility.check(n, rp.cand, hbm_bytes, cfg, workspace,
                                stage_to_device)
    if not rp.feas.ok:
        rp.verdict, rp.note = "infeasible", rp.feas.reason
        return False
    return True


def _is_managed(cand: Candidate) -> bool:
    return (cand.kind in sched.BPIPE_FAMILY
            or cand.residency not in ("none",))


def _reject_note(req: float, got: float, base_b: int) -> str:
    return (f"break-even: needs >={req:.3f}x stage gain over "
            f"1f1b b={base_b}, got {got:.3f}x")


def rank(n: Notation, cands: Iterable[Candidate], cost: CostModel,
         hbm_bytes: float, cfg: Optional[ModelConfig] = None,
         link_bw: float = NVLINK_BW,
         workspace: float = feasibility.DEFAULT_WORKSPACE,
         stage_to_device: Optional[Tuple[int, ...]] = None,
         overhead: float = 0.0,
         host_bw: float = PCIE_BW,
         exhaustive: bool = False) -> List[RankedPlan]:
    """Feasibility-prune, simulate, break-even-test and sort candidates.

    The default is a branch-and-bound search: candidates are priced with
    an admissible MFU upper bound (``mfu_upper_bound`` plus a
    residency move-time floor) before any compile or simulation, and
    skipped — verdict "pruned" — when the bound cannot beat the
    incumbent best simulated MFU, when a stall-free lower-depth twin
    makes a deeper ladder rung timeline-identical, or when a break-even
    reject at b <= baseline cannot affect any verdict or quote. The
    pruned search selects the IDENTICAL recommendation per attention arm
    as ``exhaustive=True`` (which simulates every feasible candidate —
    the escape hatch and the differential-test oracle); see
    docs/planner.md "Search performance" for the argument.

    ``overhead`` inflates the break-even bar by a fractional BPipe cost
    (``estimator.required_stage_gain``'s knob); 0.0 mirrors the paper's
    "temporarily ignore the overhead" idealization — the simulator still
    charges the traffic it can see. ``host_bw`` prices host_offload's
    D2H/H2D copies (PCIe-class by default — the bandwidth asymmetry vs.
    ``link_bw`` is exactly what the residency contest is about);
    selective_recompute is FLOPs-costed by the simulator's RECOMPUTE
    handler instead.
    """
    plans = [RankedPlan(cand=cand,
                        feas=feasibility.Feasibility(False, "not evaluated"))
             for cand in cands]
    if exhaustive:
        for rp in plans:
            if _check_feas(rp, n, hbm_bytes, cfg, workspace,
                           stage_to_device):
                _price(rp, n, cost, link_bw, host_bw)
        _break_even_pass(n, plans, cost, overhead)
    else:
        arms = []
        for rp in plans:
            if rp.cand.attention not in arms:
                arms.append(rp.cand.attention)
        for att in arms:
            _rank_arm(n, [rp for rp in plans if rp.cand.attention == att],
                      cost, hbm_bytes, cfg, link_bw, workspace,
                      stage_to_device, overhead, host_bw)

    # move_time breaks equal-MFU ties: at the same simulated throughput,
    # prefer the plan with the least residency traffic in flight (less
    # exposure to link contention the model cannot see).
    plans.sort(key=lambda p: (VERDICT_ORDER[p.verdict], -p.mfu, p.move_time))
    return plans


def _break_even_pass(n: Notation, plans: List[RankedPlan], cost: CostModel,
                     overhead: float) -> None:
    """§4 break-even pass, per attention arm, against the best feasible
    UNMANAGED plain-1F1B plan (the paper's baseline schedule — a
    residency-managed 1f1b is a contender, not the baseline). Every
    residency-managed plan faces the same bar: its whole point is
    unlocking a larger micro batch, so it must deliver the stage gain
    eq. 4 demands, whichever mechanism pays for the memory."""
    for att in {p.cand.attention for p in plans}:
        arm = [p for p in plans if p.cand.attention == att]
        base_cands = [p for p in arm if p.cand.kind == "1f1b"
                      and p.cand.residency == "none"]
        base = max((p for p in base_cands if p.ok),
                   key=lambda p: p.mfu, default=None)
        for p in arm:
            c = p.cand
            if not p.ok or not _is_managed(c):
                continue
            if base is None:
                # distinguish "nothing unmanaged fits" (residency
                # genuinely enables the arm) from "the caller excluded
                # the baseline from the search" — only the former is a
                # claim about memory
                p.note = ("no feasible 1f1b baseline "
                          "(residency enables the arm)" if base_cands
                          else "unmanaged 1f1b baseline not searched "
                               "(break-even untested)")
                continue
            req = _required_gain(n, c, base.cand, overhead)
            got = cost.stage_gain(n, c.b, base.cand.b, att)
            p.required_gain, p.achieved_gain = req, got
            p.baseline_b = base.cand.b
            if got + 1e-12 < req:
                p.verdict = "reject"
                p.note = _reject_note(req, got, base.cand.b)


def _rank_arm(n: Notation, arm: List[RankedPlan], cost: CostModel,
              hbm_bytes: float, cfg: Optional[ModelConfig], link_bw: float,
              workspace: float,
              stage_to_device: Optional[Tuple[int, ...]],
              overhead: float, host_bw: float) -> None:
    """Branch-and-bound over one attention arm.

    Funnel: (1) the unmanaged plain-1f1b baselines simulate in
    bound-descending order under an incumbent (a pruned baseline can
    never be the arm's best baseline: its MFU <= bound < some simulated
    MFU); (2) managed candidates failing the cost-only break-even test
    split into raised (b > baseline b — always simulated: they carry the
    rejection quote in the recommendation line) and unraised (pruned,
    unless no raised reject is feasible, in which case all of them are
    evaluated so the quote's fallback path sees the same set as
    exhaustive search); (3) everything else simulates in bound-descending
    order under the incumbent, with stall-free depth dominance inside
    transfer-depth ladders. Every candidate whose simulated MFU could tie
    or beat the final maximum is simulated (bound >= MFU and strictly-
    below-incumbent pruning), so the post-sort recommendation — and the
    stable tie-break, since ``plans`` keeps enumeration order — is
    identical to exhaustive search."""
    att = arm[0].cand.attention
    bound_cache: dict = {}

    def bound(rp: RankedPlan) -> float:
        key = rp.cand
        b = bound_cache.get(key)
        if b is None:
            b = bound_cache[key] = mfu_upper_bound(n, rp.cand, cost,
                                                   link_bw)
        rp.mfu_bound = b
        return b

    def feas_ok(rp: RankedPlan) -> bool:
        return _check_feas(rp, n, hbm_bytes, cfg, workspace,
                           stage_to_device)

    # -- (1) baselines ---------------------------------------------------
    base_cands = [rp for rp in arm if rp.cand.kind == "1f1b"
                  and rp.cand.residency == "none"]
    incumbent = float("-inf")
    for rp in sorted(base_cands, key=lambda r: -bound(r)):
        if bound(rp) < incumbent - PRUNE_MARGIN:
            rp.verdict = "pruned"
            rp.note = (f"ideal-bound {bound(rp) * 100:.2f}% MFU "
                       f"< incumbent {incumbent * 100:.2f}%")
            continue
        if feas_ok(rp):
            _price(rp, n, cost, link_bw, host_bw)
            if rp.mfu > incumbent:
                incumbent = rp.mfu
    base = max((rp for rp in base_cands if rp.ok),
               key=lambda r: r.mfu, default=None)

    # -- (2) classify the rest against the cost-only break-even test -----
    contenders: List[RankedPlan] = []
    rej_raised: List[RankedPlan] = []
    rej_unraised: List[RankedPlan] = []
    gains: dict = {}
    for rp in arm:
        c = rp.cand
        if c.kind == "1f1b" and c.residency == "none":
            continue
        if base is not None and _is_managed(c):
            req = _required_gain(n, c, base.cand, overhead)
            got = cost.stage_gain(n, c.b, base.cand.b, att)
            gains[id(rp)] = (req, got)
            if got + 1e-12 < req:
                (rej_raised if c.b > base.cand.b
                 else rej_unraised).append(rp)
                continue
        contenders.append(rp)

    def set_gains(rp: RankedPlan) -> Tuple[float, float]:
        req, got = gains[id(rp)]
        rp.required_gain, rp.achieved_gain = req, got
        rp.baseline_b = base.cand.b
        return req, got

    # -- (3) contenders under the incumbent ------------------------------
    stall_free: dict = {}   # depth-ladder twin key -> simulated rung
    for rp in sorted(contenders, key=lambda r: -bound(r)):
        c = rp.cand
        if bound(rp) < incumbent - PRUNE_MARGIN:
            rp.verdict = "pruned"
            rp.note = (f"ideal-bound {bound(rp) * 100:.2f}% MFU "
                       f"< incumbent {incumbent * 100:.2f}%")
            continue
        twin_key = (c.kind, c.b, c.v, c.cap, c.residency, c.seq_chunks,
                    c.vocab_parallel)
        twin = stall_free.get(twin_key)
        if twin is not None and twin.cand.depth < c.depth:
            # Zero-stall dominance: deeper overlap can only start moves
            # earlier; with no stall to hide the compute timeline (and
            # with it makespan/MFU/move_time) is identical, and the
            # stable tie-break prefers the shallower rung.
            rp.verdict = "pruned"
            rp.note = (f"depth={twin.cand.depth} twin is "
                       f"stall-free — identical timeline, loses the "
                       f"depth tie-break")
            continue
        if not feas_ok(rp):
            continue
        if spec_moves_data(c, n.p):
            floor = _move_floor(n, rp, cost, link_bw, host_bw)
            if floor > 0.0:
                fb = (cost.full_flops(n)
                      / (max(floor, 1e-300) * n.p * n.t
                         * cost.peak_per_chip))
                if fb < incumbent - PRUNE_MARGIN:
                    rp.mfu_bound = min(rp.mfu_bound or fb, fb)
                    rp.verdict = "pruned"
                    rp.note = (f"move-time floor caps MFU at "
                               f"{fb * 100:.2f}% < incumbent "
                               f"{incumbent * 100:.2f}%")
                    continue
        _price(rp, n, cost, link_bw, host_bw)
        if base is not None and _is_managed(c):
            set_gains(rp)
        elif _is_managed(c):
            rp.note = ("no feasible 1f1b baseline "
                       "(residency enables the arm)" if base_cands
                       else "unmanaged 1f1b baseline not searched "
                            "(break-even untested)")
        if rp.load_stall == 0.0 and twin_key not in stall_free:
            stall_free[twin_key] = rp
        if rp.mfu > incumbent:
            incumbent = rp.mfu

    # -- (4) break-even rejects ------------------------------------------
    feasible_raised = False
    for rp in rej_raised:
        if not feas_ok(rp):
            continue
        _price(rp, n, cost, link_bw, host_bw)
        req, got = set_gains(rp)
        rp.verdict = "reject"
        rp.note = _reject_note(req, got, base.cand.b)
        feasible_raised = True
    for rp in rej_unraised:
        if feasible_raised:
            # the recommendation line quotes the highest-MFU RAISED
            # reject when one exists; an unraised reject can neither be
            # quoted nor recommended — record the verdict without
            # compiling or simulating it
            req, got = set_gains(rp)
            rp.verdict = "pruned"
            rp.note = (_reject_note(req, got, base.cand.b)
                       + " (b <= baseline: not simulated)")
        elif feas_ok(rp):
            _price(rp, n, cost, link_bw, host_bw)
            req, got = set_gains(rp)
            rp.verdict = "reject"
            rp.note = _reject_note(req, got, base.cand.b)


def spec_moves_data(cand: Candidate, p: int) -> bool:
    """Does this candidate's residency mechanism move bytes over a
    channel (swap or host offload — the move-floor pricing families)?"""
    return cand.spec(p).policy.moves_data


def recommend(ranked: List[RankedPlan],
              attention: Optional[str] = None) -> Optional[RankedPlan]:
    """The plan the planner stands behind: best simulated MFU among
    feasible plans that survived the break-even test."""
    for p in ranked:
        if p.ok and (attention is None or p.cand.attention == attention):
            return p
    return None


def arms_of(ranked: List[RankedPlan]) -> List[str]:
    seen = [a for a in ATTENTION_ARMS
            if any(p.cand.attention == a for p in ranked)]
    return seen
