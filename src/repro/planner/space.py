"""The planner's search space: candidate (kind, residency, v, b, m, cap,
attention) plans for one (model, p, t, B, s) training configuration.

A candidate is everything the user would otherwise pick by hand per
config. Enumeration applies only *structural* constraints (b | B,
interleaving's m % p == 0 and v >= 2, p*v <= num_layers, cap >= 2);
memory pruning is ``planner.feasibility``'s job and cost ranking is
``planner.rank``'s, so each stage of the funnel is testable alone.

Residency is a real dimension: unbalanced kinds pair with every policy
in ``SearchSpace.residencies`` (each active policy opening its own cap
ladder), while balanced kinds carry their built-in ``bpipe_swap`` — so
the planner's three-way contest (swap vs. offload vs. recompute, the
paper's Table 3 story) falls out of one enumeration instead of
hard-coded arms.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

from repro.core import plan as P
from repro.core import schedule as sched
from repro.core.notation import Notation
from repro.memory import policy as respol

ATTENTION_ARMS = ("none", "recompute", "flash")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space: a schedule variant plus the two
    knobs that are not the schedule's identity (micro batch size and
    attention arm). ``spec(p)`` yields the compiled-plan identity every
    downstream stage consumes.

    ``cap`` is None when nothing caps the stash and for the default
    bound (``schedule_cap`` / the policy's ``default_cap``); a
    planner-chosen override otherwise. ``v`` is 1 for plain kinds.
    ``residency`` is the activation-residency policy (balanced kinds
    carry their built-in ``bpipe_swap``). ``depth`` is the
    transfer-overlap depth (docs/transfer.md): how many residency moves
    may be in flight per channel — deeper overlap hides slower links at
    the cost of (depth - 1) extra in-flight units of device memory,
    which the feasibility pass charges.
    """
    kind: str
    b: int
    m: int
    v: int = 1
    cap: Optional[int] = None
    attention: str = "recompute"
    residency: str = "none"
    depth: int = 1
    seq_chunks: int = 1
    vocab_parallel: int = 1

    def spec(self, p: int) -> P.ScheduleSpec:
        """The candidate's schedule variant on a p-stage pipeline."""
        return P.ScheduleSpec(self.kind, p, self.m, v=self.v, cap=self.cap,
                              residency=self.residency, depth=self.depth,
                              seq_chunks=self.seq_chunks,
                              vocab_parallel=self.vocab_parallel)

    def label(self) -> str:
        bits = [self.kind, f"b={self.b}", f"m={self.m}"]
        if self.kind in sched.INTERLEAVED:
            bits.append(f"v={self.v}")
        if self.seq_chunks != 1:
            bits.append(f"c={self.seq_chunks}")
        if self.vocab_parallel != 1:
            bits.append(f"vp={self.vocab_parallel}")
        if self.residency not in ("none", "bpipe_swap"):
            bits.append(f"res={self.residency}")
        if self.cap is not None:
            bits.append(f"cap={self.cap}")
        if self.depth != 1:
            bits.append(f"d={self.depth}")
        bits.append(self.attention)
        return " ".join(bits)


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Which axes to sweep. Defaults mirror the paper's experiment grid
    plus the beyond-paper interleaved kinds and residency policies."""
    kinds: Tuple[str, ...] = ("1f1b", "bpipe",
                              "1f1b_interleaved", "bpipe_interleaved")
    attentions: Tuple[str, ...] = ATTENTION_ARMS
    vs: Tuple[int, ...] = (2, 4)
    # Offsets from the schedule's default cap. 0 first so ties between
    # equivalent caps resolve to the paper's bound; +k trades evictor
    # memory for less eviction traffic, -k the reverse.
    cap_deltas: Tuple[int, ...] = (0, 1, -1)
    max_b: int = 0          # 0 = up to B
    # Residency policies paired with each UNBALANCED kind (balanced
    # kinds embed bpipe_swap). "none" keeps the un-managed baseline in
    # the table.
    residencies: Tuple[str, ...] = ("none", "host_offload",
                                    "selective_recompute")
    # Transfer-overlap depths searched for residency-managed plans
    # (depth 1 = the serialized classic, listed first so ties between
    # equal-makespan depths resolve to the cheapest memory profile).
    depths: Tuple[int, ...] = (1, 2)
    # Sequence slices per microbatch (SlimPipe direction,
    # docs/longcontext.md). Opt-in: the default searches only the
    # unsliced classic so the paper-condition verdicts (Table 3) are
    # untouched; long-context sweeps pass e.g. (1, 2, 4). c > 1 applies
    # only to kinds with a sliced builder (``ScheduleKind.sliced``) and
    # to sequence lengths c divides; 1 first so ties resolve unsliced.
    seq_chunkses: Tuple[int, ...] = (1,)
    # Vocabulary-parallel degrees (docs/memory.md "Vocab accounting"):
    # vp > 1 scatters the embedding/head/logits spike over vp boundary
    # stages for per-microbatch collective traffic. Opt-in like
    # seq_chunkses — the default searches only the unscattered classic
    # so the paper-condition verdicts (Table 3) are untouched; large-
    # vocab sweeps pass e.g. (1, 2, 4). vp is clamped to vp <= p at
    # enumeration; 1 first so ties resolve unscattered.
    vocab_parallels: Tuple[int, ...] = (1,)


def micro_batch_sizes(B: int, max_b: int = 0) -> List[int]:
    """Power-of-two micro batch sizes dividing B (the paper's ladder)."""
    out, b = [], 1
    while b <= B and (not max_b or b <= max_b):
        if B % b == 0:
            out.append(b)
        b *= 2
    return out


def _cap_ladder(default: int, roof: int,
                deltas: Tuple[int, ...]) -> List[Optional[int]]:
    """Planner cap offsets around a default bound, clamped to [2, roof]
    (at/above the roof the rewrite degenerates to the unmanaged twin)."""
    caps: List[Optional[int]] = []
    seen = set()
    for d in deltas:
        cap = min(max(default + d, 2), roof)
        if cap in seen:
            continue
        seen.add(cap)
        caps.append(None if cap == default else cap)
    return caps


def _caps_for(kind: str, p: int, v: int, deltas: Tuple[int, ...],
              m: int, seq_chunks: int = 1) -> List[Optional[int]]:
    # Anything at or above the plain-schedule peak never evicts — the
    # candidate degenerates to its non-BPipe twin, so clamp at the
    # kind's registered roof (stage-0 peak closed forms; see the
    # ``ScheduleKind.cap_roof`` entries in core/schedule.py). Sliced
    # schedules count slice units: default and roof both widen by the
    # extra warmup slices so the delta ladder stays centered.
    extra = seq_chunks - 1
    return _cap_ladder(sched.schedule_cap(kind, p, v,
                                          seq_chunks=seq_chunks),
                       sched.SCHEDULES[kind].cap_roof(p, m, v) + extra,
                       deltas)


def _residency_caps(pol: "respol.ResidencyPolicy", p: int, v: int,
                    deltas: Tuple[int, ...], m: int,
                    seq_chunks: int = 1) -> List[Optional[int]]:
    extra = seq_chunks - 1
    return _cap_ladder(pol.default_cap(p, v) + extra,
                       pol.cap_roof(p, m, v) + extra, deltas)


def enumerate_candidates(n: Notation, space: SearchSpace = SearchSpace(),
                         num_layers: int = 0) -> Iterator[Candidate]:
    """Yield every structurally valid candidate for Notation ``n``
    (attention arms x kinds x residencies x b x v x cap). ``num_layers``
    (0 = skip the check) bounds p*v for interleaved kinds."""
    p = n.p
    # vocab-parallel degrees scatter over pipeline stages, so vp > p is
    # structurally meaningless (the spec would reject it)
    vps = [vp for vp in space.vocab_parallels if 1 <= vp <= p] or [1]
    for attention in space.attentions:
        for b in micro_batch_sizes(n.B, space.max_b):
            m = n.B // b
            for kind in space.kinds:
                assert kind in sched.SCHEDULES, kind
                entry = sched.SCHEDULES[kind]
                vs = space.vs if entry.interleaved else (1,)
                for v in vs:
                    if entry.interleaved:
                        if v < 2 or m % p != 0:
                            continue
                        if num_layers and p * v > num_layers:
                            continue
                    elif num_layers and p > num_layers:
                        continue
                    # sequence slicing (seq_chunks > 1) applies only to
                    # kinds with a sliced builder and to sequence
                    # lengths the chunk count divides
                    chunkses = [c for c in space.seq_chunkses
                                if c == 1 or (entry.sliced
                                              and n.s % c == 0)]
                    for c, vp in ((c, vp) for c in chunkses for vp in vps):
                        if entry.balanced:
                            # balanced kinds ARE the swap policy; the cap
                            # ladder is theirs, and each cap opens the
                            # overlap-depth ladder
                            for cap in _caps_for(kind, p, v,
                                                 space.cap_deltas, m, c):
                                for depth in space.depths:
                                    yield Candidate(kind=kind, b=b, m=m,
                                                    v=v, cap=cap,
                                                    attention=attention,
                                                    residency="bpipe_swap",
                                                    depth=depth,
                                                    seq_chunks=c,
                                                    vocab_parallel=vp)
                            continue
                        for residency in space.residencies:
                            pol = respol.POLICIES.get(residency)
                            assert pol is not None and not pol.swap, \
                                residency
                            caps = (_residency_caps(pol, p, v,
                                                    space.cap_deltas, m, c)
                                    if pol.active else [None])
                            # depth only matters when bytes move on a
                            # channel
                            depths = (space.depths if pol.moves_data
                                      else (1,))
                            for cap in caps:
                                for depth in depths:
                                    yield Candidate(kind=kind, b=b, m=m,
                                                    v=v, cap=cap,
                                                    attention=attention,
                                                    residency=residency,
                                                    depth=depth,
                                                    seq_chunks=c,
                                                    vocab_parallel=vp)
