"""Trace-calibrated simulator costs: fit Tf/Tb/eviction times from the
executor's per-instruction event trace and replay them through the
discrete-event simulator.

This closes the paper's §4 loop programmatically: instead of quoting
measured single-stage MFUs, run the real runtime (``PipelineExecutor``
with ``step(..., trace=True)``), fit per-op medians, and feed the
simulator/planner the observed numbers. ``measure_stage_gain`` is the
paper's "two cheap single-stage measurements" recipe end to end: two
single-stage (p=1) runs at micro batch sizes by -> bx yield the stage
gain that ``estimator.required_stage_gain`` weighs against the bubble
penalty.

Traces export to Chrome trace format (chrome://tracing, Perfetto) and
round-trip back for offline fitting.
"""
from __future__ import annotations

import dataclasses
import re
import statistics
from typing import Dict, List, Optional

from repro.core import plan
from repro.core import schedule
from repro.core import simulator as SIM
from repro.core.notation import Notation
from repro.core.schedule import B, EVICT, F, LOAD
from repro.obs import export as _export
from repro.planner.rank import AnalyticCostModel, CostModel


@dataclasses.dataclass(frozen=True)
class CalibratedCosts:
    """Per-device, per-microbatch times (seconds) fit from a trace.

    Tf/Tb are whole-device costs: interleaved traces time 1/v-sized chunk
    instructions, so the fit multiplies the chunk median back by v —
    matching ``SimConfig``'s convention (the simulator divides by v
    again). Sequence-sliced traces (``seq_chunks`` > 1) time 1/c-sized
    slice instructions the same way, so the fit multiplies by c too;
    EVICT/LOAD stay per-unit (a sliced unit IS the slice)."""
    Tf: float
    Tb: float
    t_evict: float = 0.0
    t_load: float = 0.0
    v: int = 1
    b: int = 0              # micro batch the trace ran at (0 = unknown)
    samples: int = 0
    seq_chunks: int = 1

    @property
    def t_move(self) -> float:
        """One balanced EVICT/LOAD transfer estimate."""
        pair = [t for t in (self.t_evict, self.t_load) if t > 0]
        return statistics.mean(pair) if pair else 0.0


_SLICE_RE = re.compile(r"\.s\d+")


def fit_trace(events, v: int = 1, b: int = 0,
              seq_chunks: int = 1) -> CalibratedCosts:
    """Fit simulator costs from an executor event stream — canonical
    ``repro.obs.events.Span``s (``step(trace=True)`` or a reloaded
    trace; medians — robust to the odd scheduler hiccup; trace a warmed
    step, not the compile step). All slices of an op fold into one list
    and the F/B medians multiply back by ``seq_chunks`` (a slice is 1/c
    of the microbatch), mirroring the ``v`` convention. WAIT halves
    (``Span.phase``) and channel-occupancy spans are completion/queue
    bookkeeping, not instruction costs — they bin separately and stay
    out of the fit. Legacy string-suffixed ops (``F.s0``, ``LOAD+w``)
    from pre-obs traces still bin correctly."""
    by_op: Dict[str, List[float]] = {F: [], B: [], EVICT: [], LOAD: []}
    n = 0
    for e in events:
        n += 1
        if getattr(e, "track", "compute") == "channel":
            continue
        # residency ops (OFFLOAD/FETCH/DROP/RECOMPUTE, plugin policies)
        # are collected too — only F/B/EVICT/LOAD feed the fit
        op = _SLICE_RE.sub("", e.op)
        if getattr(e, "phase", "") == "wait" and not op.endswith("+w"):
            op += "+w"
        by_op.setdefault(op, []).append(e.duration)
    assert by_op[F] and by_op[B], "trace has no F/B instructions"
    med = {op: (statistics.median(ds) if ds else 0.0)
           for op, ds in by_op.items()}
    return CalibratedCosts(
        Tf=med[F] * v * seq_chunks, Tb=med[B] * v * seq_chunks,
        t_evict=med[EVICT], t_load=med[LOAD],
        v=v, b=b, samples=n, seq_chunks=seq_chunks)


def apply(costs: CalibratedCosts, cfg: SIM.SimConfig) -> SIM.SimConfig:
    """A SimConfig re-grounded in measured compute times. Eviction traffic
    keeps its analytic bytes/bandwidth model: on one host the store move
    is bookkeeping, so its measured duration says nothing about a real
    pair link."""
    return dataclasses.replace(cfg, Tf=costs.Tf, Tb=costs.Tb)


def replay(costs: CalibratedCosts, kind, p: Optional[int] = None,
           m: Optional[int] = None, v: int = 2,
           cap: Optional[int] = None, evict_bytes: float = 0.0,
           pair_bw: float = float("inf"), pair_hops: int = 1,
           t_p2p: float = 0.0) -> SIM.SimResult:
    """Simulate a schedule variant under the fitted costs. ``kind`` is a
    ``plan.ScheduleSpec`` (preferred) or a legacy kind name with the
    (p, m, v, cap) knobs."""
    if not isinstance(kind, plan.ScheduleSpec):
        kind = plan.ScheduleSpec(
            kind, p, m, v=v,
            cap=cap if kind in schedule.BPIPE_FAMILY else None)
    return SIM.simulate(SIM.SimConfig(
        spec=kind, Tf=costs.Tf, Tb=costs.Tb,
        evict_bytes=evict_bytes, pair_bw=pair_bw, pair_hops=pair_hops,
        t_p2p=t_p2p))


class TraceCostModel(CostModel):
    """CostModel anchored at one measured (b, T) point. Other micro batch
    sizes scale by the saturating-efficiency shape (T(b) proportional to
    b / eff(b), eff(b) = b/(b+k)) — a one-point version of
    ``estimator.fit_stage_mfu``'s curve.

    ``attention`` names the arm the trace ran under; other arms scale by
    the analytic time-factor ratios (a trace taken without recompute says
    nothing about recompute's re-forward cost, so the model must charge
    it rather than rank all arms at the traced time)."""

    def __init__(self, costs: CalibratedCosts, k: float = 0.25,
                 peak_per_chip: float = None, attention: str = "none"):
        assert costs.b > 0, "trace must record its micro batch size b"
        self.costs = costs
        self.k = k
        self._factors = AnalyticCostModel.TIME_FACTOR
        self.traced_attention = attention
        assert attention in self._factors, attention
        if peak_per_chip is not None:
            self.peak_per_chip = peak_per_chip

    def stage_T(self, n: Notation, attention: str) -> float:
        b0, b = self.costs.b, n.b
        T0 = self.costs.Tf + self.costs.Tb
        eff0 = b0 / (b0 + self.k)
        eff = b / (b + self.k)
        arm = (self._factors[attention]
               / self._factors[self.traced_attention])
        return T0 * (b / b0) * (eff0 / eff) * arm


# ---------------------------------------------------------------------------
# Chrome trace round trip — aliases into the unified exporter
# ---------------------------------------------------------------------------
# The ad-hoc serializer that used to live here (which dropped the
# WAIT-half ``+w`` and slice ``.sN`` distinctions on reload, mis-binning
# move medians on replayed calibrations) is replaced by ``repro.obs.
# export``: structured args round-trip every span field losslessly, and
# the loader still parses old-format traces by suffix.
chrome_trace = _export.to_chrome
save_chrome_trace = _export.save_trace
load_chrome_trace = _export.load_trace


# ---------------------------------------------------------------------------
# The §4 recipe: two cheap single-stage measurements
# ---------------------------------------------------------------------------
def measure_stage_T(cfg, b: int, seq: int = 32, m: int = 2,
                    remat: str = "none"):
    """Run ONE pipeline stage (p=1, the whole model) for m microbatches of
    size b and return (T, costs): T = median F + median B seconds. The
    first (compile) step is discarded; the second is traced."""
    import jax
    from repro.models import model as M
    from repro.pipeline.executor import PipelineExecutor

    ex = PipelineExecutor(cfg, p=1, kind="1f1b", micro_batch=b, remat=remat)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (m * b, seq + 1),
                              0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ex.step(params, batch)                       # warm / compile
    res = ex.step(params, batch, trace=True)
    costs = fit_trace(res.events, v=1, b=b)
    return costs.Tf + costs.Tb, costs


def measure_stage_gain(cfg, bx: int, by: int, seq: int = 32, m: int = 2,
                       remat: str = "none") -> dict:
    """The paper's decision procedure, measured: stage gain
    MFU_stage(bx)/MFU_stage(by) = (bx/T(bx)) / (by/T(by)). Compare with
    ``estimator.required_stage_gain`` before writing any BPipe code."""
    Tx, cx = measure_stage_T(cfg, bx, seq, m, remat)
    Ty, cy = measure_stage_T(cfg, by, seq, m, remat)
    return {"bx": bx, "by": by, "Tx": Tx, "Ty": Ty,
            "gain": (bx / Tx) / (by / Ty),
            "costs_x": cx, "costs_y": cy}
