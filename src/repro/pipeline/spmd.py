"""Compiled SPMD pipeline parallelism: shard_map + collective_permute.

This is the *lowering/scale* half of the pipeline story (the interpreter
in executor.py is the *memory-semantics* half — see DESIGN.md §5.3):

  * stages live on the ``stage`` mesh axis (the production mesh's "model"
    axis), activations flow stage->stage+1 through ``lax.ppermute``;
  * microbatches stream GPipe-style over m + p - 1 ticks inside one
    ``lax.scan`` => the HLO is O(1) in both depth and microbatch count;
  * per-tick stage compute is rematerialized (jax.checkpoint), bounding
    stash memory to tick-boundary states (XLA/GSPMD cannot express true
    MPMD 1F1B stash rotation — this is a documented platform adaptation);
  * ``bpipe_stash=True`` applies the BPipe eviction pattern to the saved
    tick-boundary activation: the autodiff residual is shipped to the
    paired stage after the forward tick and fetched back in the backward
    — two extra collective-permutes per tick whose bytes are visible to
    the roofline pass (kernels of the paper's Fig. 1 arrows).

Uniform stages required: num_layers % p == 0 (true for the paper's
GPT-3/LLaMA at p = 16, the Fig. 2 configuration).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.bpipe import pair_adjacent_layout
from repro.models.blocks import apply_layer, init_layer
from repro.models.layers import (apply_norm, embed, init_embed, init_norm,
                                 unembed)


# ---------------------------------------------------------------------------
# Parameters: stage-stacked
# ---------------------------------------------------------------------------
def init_pipeline_params(key, cfg: ModelConfig, p: int):
    """Per-stage stacked layer params (leading dim p) + shared head/tail."""
    assert cfg.num_layers % p == 0, (cfg.num_layers, p)
    per = cfg.num_layers // p
    kinds = cfg.layer_kinds()
    assert all(k == kinds[0] for k in kinds) or per % len(cfg.block_pattern) == 0, \
        "stage boundaries must align with the block pattern"

    def init_stage(k):
        ks = jax.random.split(k, per)
        return [init_layer(ks[j], cfg, kinds[j]) for j in range(per)]

    keys = jax.random.split(key, p)
    stages = jax.vmap(init_stage)(keys)  # leaves: (p, ...)
    return {
        "stages": stages,
        "embed": init_embed(jax.random.fold_in(key, 1), cfg),
        "final_norm": init_norm(cfg),
    }


# ---------------------------------------------------------------------------
# BPipe remote stash (custom_vjp around the per-tick stage compute)
# ---------------------------------------------------------------------------
def _remote_remat(fn, perm_out, perm_back, axis):
    """Recompute-in-backward whose saved input lives on the BPipe partner.

    fwd: y = fn(params, x); residual = ppermute(x -> partner)
    bwd: x = ppermute(residual -> back); grads = vjp(fn)(g)
    """

    @jax.custom_vjp
    def wrapped(params, x):
        return fn(params, x)

    def fwd(params, x):
        y = fn(params, x)
        stash = jax.lax.ppermute(x, axis, perm_out)   # EVICT
        return y, (params, stash)

    def bwd(res, g):
        params, stash = res
        x = jax.lax.ppermute(stash, axis, perm_back)  # LOAD
        _, vjp_fn = jax.vjp(fn, params, x)
        return vjp_fn(g)

    wrapped.defvjp(fwd, bwd)
    return wrapped


def _bpipe_perms(p: int):
    """Device-level permutation pairs for the eviction hop. With the
    pair-adjacent layout stages sit so each (x, p-1-x) pair is 1 ICI hop
    apart; on the raw stage axis the permutation is stage->partner."""
    pairs = [(x, p - 1 - x) for x in range(p // 2)]
    perm_out = [(a, b) for a, b in pairs] + [(b, a) for a, b in pairs]
    if p % 2:
        mid = p // 2
        perm_out.append((mid, mid))
    return perm_out, perm_out  # involution: same permutation both ways


# ---------------------------------------------------------------------------
# The pipelined loss
# ---------------------------------------------------------------------------
def pipeline_loss_fn(cfg: ModelConfig, p: int, num_micro: int, *,
                     stage_axis: str = "model", data_axis="data",
                     bpipe_stash: bool = False, remat: bool = True):
    """Returns loss(params, batch) to be used under shard_map/jit.

    batch: tokens/labels (local_batch, s) already sharded over data axes.
    Must be called inside shard_map over (data_axis, stage_axis).
    """
    per = cfg.num_layers // p
    kinds = cfg.layer_kinds()

    def stage_compute(stage_params, x):
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        for j in range(per):
            # inside shard_map the stage-stacked leading dim is local (=1)
            lp = jax.tree.map(lambda a: a[0], stage_params[j])
            x, _ = apply_layer(lp, x, cfg, kinds[j], positions)
        return x

    perm_out, perm_back = _bpipe_perms(p)
    if bpipe_stash:
        stage_fn = _remote_remat(stage_compute, perm_out, perm_back, stage_axis)
    elif remat:
        stage_fn = jax.checkpoint(stage_compute)
    else:
        stage_fn = stage_compute

    shift = [(i, (i + 1) % p) for i in range(p)]

    def loss_fn(params, batch):
        idx = jax.lax.axis_index(stage_axis)
        tokens, labels = batch["tokens"], batch["labels"]
        bsz, s = tokens.shape
        assert bsz % num_micro == 0, (bsz, num_micro)
        mb = bsz // num_micro
        tok_mb = tokens.reshape(num_micro, mb, s)
        lbl_mb = labels.reshape(num_micro, mb, s)
        pad = jnp.zeros((p - 1, mb, s), tokens.dtype)
        tok_stream = jnp.concatenate([tok_mb, pad], 0)
        lbl_stream = jnp.concatenate(
            [jnp.full((p - 1, mb, s), -1, labels.dtype), lbl_mb], 0)

        vaxes0 = (stage_axis,) + (tuple(data_axis) if data_axis else ())
        state0 = compat.pvary(
            jnp.zeros((mb, s, cfg.d_model), jnp.dtype(cfg.dtype)), vaxes0)

        def tick(state, xs):
            tok_t, lbl_t = xs
            # stage 0 injects the next microbatch's embeddings
            inj = embed(params["embed"], tok_t, cfg)
            x = jnp.where(jnp.equal(idx, 0)[None, None, None], inj, state)
            y = stage_fn(params["stages"], x)

            # Microbatch loss, masked to the last stage. Uniform-SPMD: all
            # stages run the vocab matmul and multiply by an indicator.
            # (A lax.cond gate deadlocks here: replicated params used
            # inside a device-varying cond acquire pvary->psum transposes
            # that only the true-branch devices execute. The extra FLOPs
            # are netted out analytically in the roofline pass.)
            xl = apply_norm(params["final_norm"], y)
            logits = unembed(params["embed"], xl, cfg)
            mask = (lbl_t >= 0).astype(jnp.float32)
            lbl = jnp.maximum(lbl_t, 0)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, lbl[..., None], -1)[..., 0]
            mb_loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            loss_t = mb_loss * jnp.equal(idx, p - 1).astype(jnp.float32)
            state = jax.lax.ppermute(y, stage_axis, shift)
            return state, loss_t

        _, losses = jax.lax.scan(tick, state0, (tok_stream, lbl_stream))
        total = jnp.sum(losses) / num_micro
        total = jax.lax.psum(total, stage_axis)
        if data_axis is not None:
            total = jax.lax.pmean(total, data_axis)
        return total

    return loss_fn


def make_spmd_train_loss(cfg: ModelConfig, mesh, p: int, num_micro: int,
                         *, bpipe_stash: bool = False):
    """shard_map-wrapped pipeline loss on the production mesh: the "model"
    axis carries stages, remaining axes carry data."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    inner = pipeline_loss_fn(cfg, p, num_micro, stage_axis="model",
                             data_axis=data_axes, bpipe_stash=bpipe_stash)

    def loss(params, batch):
        in_specs = (
            {"stages": jax.tree.map(lambda _: P("model"),
                                    params["stages"]),
             "embed": jax.tree.map(lambda _: P(), params["embed"]),
             "final_norm": jax.tree.map(lambda _: P(), params["final_norm"])},
            {"tokens": P(data_axes), "labels": P(data_axes)},
        )
        f = compat.shard_map(inner, mesh=mesh, in_specs=in_specs, out_specs=P())
        return f(params, batch)

    return loss
