"""Split a full model into pipeline stages (Megatron-style layer ranges).

``split_params`` regroups the PatternStack's stacked parameters into
per-stage, per-layer params; ``merge_stage_grads`` restacks gradients into
the original structure so the optimizer is pipeline-agnostic. Tied
embeddings are replicated onto the first and last stage and their grads
summed at merge (Megatron ties them with an all-reduce the same way).

All functions are written over *virtual* stages: for interleaved
schedules with v chunks per device, pass ``p * v`` as the stage count and
index with ``virtual_stage = chunk * p + device`` — chunk c on device s
then holds the layer slice of virtual stage c*p + s, the first virtual
stage embeds, and the last computes the loss. ``StageSplitter`` hoists
the assignment/PatternStack bookkeeping so executors don't rebuild it
every step.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import (PatternStack, apply_layer,
                                 apply_layer_sliced)
from repro.models.layers import apply_norm, embed, unembed


def layer_assignment(cfg: ModelConfig, p: int) -> List[List[int]]:
    """Contiguous layer ranges per stage (uniform; remainder to late stages,
    which hold fewer in-flight activations under 1F1B)."""
    n = cfg.num_layers
    base, extra = divmod(n, p)
    sizes = [base + (1 if i >= p - extra else 0) for i in range(p)]
    out, ℓ = [], 0
    for s in sizes:
        out.append(list(range(ℓ, ℓ + s)))
        ℓ += s
    return out


class StageSplitter:
    """Per-(cfg, n_stages) split/merge with the layer assignment and
    PatternStack bookkeeping computed once (executors hold one of these
    across steps instead of rebuilding it per call)."""

    def __init__(self, cfg: ModelConfig, n_stages: int):
        self.cfg, self.n = cfg, n_stages
        self.assign = layer_assignment(cfg, n_stages)
        self.stack = PatternStack(cfg)

    def _layer_params(self, params, ℓ: int):
        k = len(self.stack.pattern)
        blk, j = divmod(ℓ, k)
        if blk < self.stack.n_full:
            return jax.tree.map(lambda a: a[blk], params["blocks"][f"pos{j}"])
        return params["blocks"][f"rem{ℓ - self.stack.n_full * k}"]

    def split(self, params) -> List[Dict[str, Any]]:
        stages = []
        for i, layers in enumerate(self.assign):
            sp: Dict[str, Any] = {
                "layers": [self._layer_params(params, ℓ) for ℓ in layers]}
            if i == 0:
                sp["embed"] = params["embed"]
            if i == self.n - 1:
                sp["final_norm"] = params["final_norm"]
                # unembed weights (tied table or separate matrix)
                sp["unembed"] = params["embed"]
            stages.append(sp)
        return stages

    def merge(self, stage_grads: List[Dict[str, Any]]):
        """Restack per-stage layer grads into full-model param structure."""
        k = len(self.stack.pattern)
        per_layer = {}
        for sg, layers in zip(stage_grads, self.assign):
            for local, ℓ in enumerate(layers):
                per_layer[ℓ] = sg["layers"][local]
        blocks: Dict[str, Any] = {}
        for j in range(k):
            rows = [per_layer[blk * k + j] for blk in range(self.stack.n_full)]
            blocks[f"pos{j}"] = jax.tree.map(lambda *a: jnp.stack(a), *rows)
        for i in range(len(self.stack.rem)):
            blocks[f"rem{i}"] = per_layer[self.stack.n_full * k + i]
        embed_grad = stage_grads[0]["embed"]
        tail = stage_grads[-1]
        embed_grad = jax.tree.map(jnp.add, embed_grad, tail["unembed"])
        return {"embed": embed_grad, "blocks": blocks,
                "final_norm": tail["final_norm"]}


def split_params(params, cfg: ModelConfig, p: int) -> List[Dict[str, Any]]:
    return StageSplitter(cfg, p).split(params)


def merge_stage_grads(stage_grads: List[Dict[str, Any]], cfg: ModelConfig,
                      p: int, params_template=None):
    return StageSplitter(cfg, p).merge(stage_grads)


# ---------------------------------------------------------------------------
# Stage forward functions
# ---------------------------------------------------------------------------
def make_stage_fn(cfg: ModelConfig, p: int, stage: int, remat: str = "none"):
    """Returns f(stage_params, x_or_tokens, batch) -> activation or loss.

    Stage 0 consumes batch tokens (embeds); the last stage returns the
    scalar mean loss for the microbatch. MoE aux-loss is folded in.
    """
    assign = layer_assignment(cfg, p)
    kinds = cfg.layer_kinds()
    layers = assign[stage]
    first, last = stage == 0, stage == p - 1

    def fn(sp, carry, batch):
        """carry = (activation, running_aux). Stage 0 builds it from tokens;
        the last stage collapses it to the scalar microbatch loss."""
        if first:
            x = embed(sp["embed"], batch["tokens"], cfg)
            aux = jnp.zeros((), jnp.float32)
        else:
            x, aux = carry
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        for local, ℓ in enumerate(layers):
            x, a = apply_layer(sp["layers"][local], x, cfg, kinds[ℓ],
                               positions, remat=remat)
            aux = aux + a
        if not last:
            return x, aux
        x = apply_norm(sp["final_norm"], x)
        logits = unembed(sp["unembed"], x, cfg)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lbl = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lbl[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + aux

    return fn


def make_sliced_stage_fn(cfg: ModelConfig, p: int, stage: int,
                         remat: str = "none"):
    """Sequence-sliced stage forward (``ScheduleSpec.seq_chunks`` > 1,
    docs/longcontext.md). Returns

        f(sp, carry, kv_prefix, batch) -> (primary, kv_own)

    where ``batch`` holds this slice's tokens/labels plus ``"offset"``
    (the slice's global start position, an int32 scalar), ``kv_prefix``
    is one (k, v) pair per local layer covering global positions
    [0, offset) — zero-length for slice 0 — and ``kv_own`` is the
    slice's own post-RoPE KV the executor retains for later slices.

    ``primary`` is (activation, aux) on interior stages and
    (nll_sum, aux) on the last stage — the nll sum is UN-normalized;
    the executor divides by the microbatch's total valid-token count so
    the summed slice losses equal the unchunked stage loss.
    """
    assign = layer_assignment(cfg, p)
    kinds = cfg.layer_kinds()
    layers = assign[stage]
    first, last = stage == 0, stage == p - 1

    def fn(sp, carry, kv_prefix, batch):
        if first:
            x = embed(sp["embed"], batch["tokens"], cfg)
            aux = jnp.zeros((), jnp.float32)
        else:
            x, aux = carry
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(
            batch["offset"].astype(jnp.int32)
            + jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        kv_own = []
        for local, ℓ in enumerate(layers):
            x, a, kv = apply_layer_sliced(
                sp["layers"][local], x, cfg, kinds[ℓ], positions,
                kv_prefix[local], remat=remat)
            aux = aux + a
            kv_own.append(kv)
        kv_own = tuple(kv_own)
        if not last:
            return (x, aux), kv_own
        x = apply_norm(sp["final_norm"], x)
        logits = unembed(sp["unembed"], x, cfg)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lbl = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lbl[..., None], axis=-1)[..., 0]
        return (jnp.sum(nll * mask), aux), kv_own

    return fn
