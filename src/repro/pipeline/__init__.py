from repro.pipeline.executor import PipelineExecutor, StepResult  # noqa: F401
