"""Executable pipeline runtime: a schedule interpreter with true 1F1B /
BPipe activation-stash semantics, chunk-aware for interleaved schedules.

This is the Megatron-equivalent layer of the reproduction: a compiled
``plan.Schedule`` is interpreted instruction-by-instruction as a handler
set over the shared dispatch engine (``plan.run``); each F runs
``jax.vjp`` on its (virtual) stage (so the stash — the vjp residuals — is
*really* held until the matching B), EVICT/LOAD move stash entries between
the evictor's and acceptor's stores (on one host this is bookkeeping plus
the byte accounting from ``core.memory_model``; on a multi-device host it
would be a device_put), and every B consumes its stash and propagates the
cotangent upstream.

Residency policies (``repro.memory``, a ``ScheduleSpec`` dimension) give
the stash other places to live: OFFLOAD/FETCH really ``jax.device_put``
the vjp closure (a ``tree_util.Partial`` pytree) to the host platform
and back; DROP frees the residuals keeping only the boundary input, and
RECOMPUTE re-runs the stage forward from it — both bit-identical to the
resident execution, which ``tests/test_residency.py`` pins. Every move
executes as its compiled ISSUE/WAIT halves (docs/transfer.md): the
ISSUE starts the async copy and registers it with the bounded-depth
transfer runtime (``repro.transfer.runtime``), the WAIT blocks on the
channel before the dependent compute touches the data — so the live
HBM bound holds on real in-flight buffers, not just on the store's
bookkeeping.

Interleaved kinds give each device v model chunks: chunk c on device s is
virtual stage ``c*p + s``; activations flow virtual stage vs -> vs+1 (the
hop from device p-1 back to device 0 crosses chunks), and every stash /
routing key is (stage, mb, chunk), so the same handler set executes plain
and interleaved streams. The dependency edges and partner map come
precompiled on the Schedule — the executor re-derives nothing.

Sequence-sliced schedules (``ScheduleSpec.seq_chunks`` = c > 1,
docs/longcontext.md) split every microbatch into c sequence slices:
each F runs one slice through ``make_sliced_stage_fn``, reading the
retained-KV prefix of all earlier slices via ``store.peek`` (a slice's
stash — vjp residuals plus its own post-RoPE KV — is just another store
unit, so every residency policy manages sliced KV with zero new
mechanism); each B runs in reverse slice order, accumulating the
KV-prefix gradients it emits onto the earlier slices' pending
cotangents in a single pass. At seq_chunks=1 the engine is bit-identical
to the unsliced path (pinned by tests/test_differential.py).

Compilation contract (tested): stage fns are built and jitted once in
``__init__`` and the microbatch is a ``jax.vjp`` *argument* — not a value
closed over by a per-call lambda — so each virtual stage traces exactly
once per activation shape and repeated ``step()`` calls recompile nothing.

Numerical contract (tested): for any schedule kind,
    executor.step(params, batch).loss == models.loss_fn(params, batch)
and gradients match to fp32 tolerance. BPipe's cap (``bpipe_cap`` /
``bpipe_interleaved_cap``) is asserted on the live store, not on paper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import memory_model as mm
from repro.core import plan as P
from repro.core import schedule as sched
from repro.core.notation import Notation
from repro.core.schedule import B, F
from repro.memory import offload as mem_offload
from repro.memory import policy as respol
# The store is re-homed to repro.memory.store; re-exported here for
# legacy importers of the executor module.
from repro.memory.store import ActivationStore, StoreStats, Unit
from repro.models import blocks as blocks_mod
from repro.obs.events import Observer, Recorder, Span
from repro.pipeline import stage as stage_mod
from repro.transfer.channel import channel_key
from repro.transfer.runtime import AsyncTransferRuntime


@dataclasses.dataclass
class StepResult:
    loss: jnp.ndarray
    grads: Any
    stats: StoreStats
    # Canonical-schema spans (repro.obs.events.Span) of the traced step,
    # wall-clock seconds relative to step start: stage instructions
    # (WAIT halves carry phase="wait") plus channel-occupancy spans from
    # the transfer runtime, each stage span sampling the store's live
    # resident bytes (Span.hbm). None unless step(trace=True).
    events: Optional[List[Span]] = None


class PipelineExecutor:
    """Interprets a pipeline schedule over a real model.

    Preferred construction passes the schedule variant as a value:

        PipelineExecutor(cfg, spec=ScheduleSpec("bpipe", p=4), micro_batch=2)

    A spec with ``m=0`` is a template the executor binds to the real
    batch at ``step()`` (m = batch_rows / micro_batch); a bound spec
    additionally pins the expected microbatch count.

    Legacy args (deprecation shims — they construct the spec):
      p: number of pipeline stages (p * v must be <= num_layers).
      kind: any registered schedule kind (``schedule.SCHEDULES``).
      v: virtual chunks per device (interleaved kinds only; ignored
        otherwise). Interleaved streams additionally require m % p == 0.
      cap: BPipe-family / residency stash-cap override (planner-chosen).
        With a non-default cap the live assertion bounds each stage by
        the schedule's own per-stage peak accounting (a tighter evictor
        cap legitimately raises the acceptor's peak above it).
      residency: activation-residency policy for plain kinds
        (``repro.memory.policy.POLICIES``; balanced kinds embed
        ``bpipe_swap``).

    Other args:
      cfg: model config (any assigned architecture).
      micro_batch: rows per microbatch (global batch must divide evenly).
      notation: optional paper-notation override for byte accounting.
    """

    def __init__(self, cfg: ModelConfig, p: Optional[int] = None,
                 kind: str = "1f1b", micro_batch: int = 1,
                 remat: str = "none", notation: Optional[Notation] = None,
                 enforce_cap: bool = True, v: int = 2,
                 cap: Optional[int] = None,
                 residency: str = "none",
                 spec: Optional[P.ScheduleSpec] = None):
        if spec is None:
            assert p is not None, "need p (or pass spec=ScheduleSpec(...))"
            assert kind in sched.SCHEDULES, kind
            spec = P.ScheduleSpec(kind, p, 0, v=v, cap=cap,
                                  residency=residency)
        else:
            assert p is None or p == spec.p, (p, spec)
        self.spec = spec
        self.cfg, self.p, self.kind = cfg, spec.p, spec.kind
        self.v = spec.v
        self.n_virtual = spec.n_virtual
        assert self.n_virtual <= cfg.num_layers, \
            (spec.p, self.v, cfg.num_layers)
        self.b = micro_batch
        self.remat = remat
        self.enforce_cap = enforce_cap
        self.cap = spec.resolved_cap
        self.c = spec.seq_chunks
        # One jitted fn per *virtual* stage, built once: jax.vjp over a
        # stable jitted callable reuses its trace, so repeated step()
        # calls (and every microbatch within a step) compile nothing new.
        # (Sliced stage fns retrace once per distinct kv-prefix length —
        # c traces per virtual stage, still O(1) across steps.)
        if self.c > 1:
            bad = set(cfg.layer_kinds()) - set(blocks_mod.SLICEABLE_KINDS)
            assert not bad, \
                f"seq_chunks>1 needs attention mixers, got {sorted(bad)}"
            self.stage_fns = [
                jax.jit(stage_mod.make_sliced_stage_fn(
                    cfg, self.n_virtual, vs, remat))
                for vs in range(self.n_virtual)]
        else:
            self.stage_fns = [
                jax.jit(stage_mod.make_stage_fn(
                    cfg, self.n_virtual, vs, remat))
                for vs in range(self.n_virtual)]
        self.splitter = stage_mod.StageSplitter(cfg, self.n_virtual)
        self.notation = notation

    # ------------------------------------------------------------------
    def _schedule_for(self, m: int) -> P.Schedule:
        if self.spec.bound:
            assert m == self.spec.m, \
                f"batch implies m={m} but spec binds m={self.spec.m}"
        return P.compile_plan(self.spec.with_m(m))

    def step(self, params, batch, trace: bool = False,
             observer: Optional[Observer] = None) -> StepResult:
        cfg, p = self.cfg, self.p
        nv = self.n_virtual
        bsz = batch["tokens"].shape[0]
        assert bsz % self.b == 0
        m = bsz // self.b
        seq = batch["tokens"].shape[1]
        n = self.notation or Notation(
            a=cfg.num_heads, b=self.b, h=cfg.d_model, l=cfg.num_layers,
            s=seq, v=cfg.vocab_size, B=bsz, p=p, t=1)
        attention = {"none": "none", "attn": "recompute", "full": "recompute",
                     "flash": "flash"}.get(self.remat, "none")
        policy = self.spec.policy
        c = self.c
        sliced = c > 1
        if sliced:
            assert seq % c == 0, f"seq {seq} not divisible by seq_chunks {c}"
        Ls = seq // c
        # One stash unit's bytes — the SAME v-chunk weighting
        # memory_model.act_bytes_per_stage charges, so executor-reported
        # peak_bytes/bytes_moved agree with the model's per-stage numbers
        # (each interleaved unit holds 1/v of the device's layers; a
        # sliced unit 1/c of the stage stash plus its retained-KV prefix).
        unit_bytes = mm.sliced_unit_bytes(n, attention, self.v, c)
        retained = policy.retained_bytes(n, attention, self.v)
        if sliced:
            # a released slice retains 1/c of the policy's usual bytes
            # plus its own KV (the DROP strip keeps (carry, kv_own) so
            # later slices' forwards still reach the prefix) — mirrors
            # memory_model.per_stage_memory
            retained = retained / c
            if policy.mechanism == "recompute":
                retained += mm.kv_bytes_per_slice(n, self.v, c)
        store = ActivationStore(p, unit_bytes, retained_bytes=retained)
        is_recompute = policy.mechanism == "recompute"
        swap_ops = frozenset(
            op for op, pol in {**respol.RELEASE_OPS,
                               **respol.RESTORE_OPS}.items() if pol.swap)

        stage_params = self.splitter.split(params)
        schedule = self._schedule_for(m)
        bounds = schedule.bounds
        partner = schedule.partner
        # trace=True attaches a Recorder when the caller brought no
        # observer of their own; with observer=None and trace=False the
        # step is the exact pre-instrumentation code path (zero-cost —
        # no timing, no blocking, no span construction).
        recorder: Optional[Recorder] = None
        if trace and observer is None:
            observer = recorder = Recorder()
        elif trace:
            assert isinstance(observer, Recorder), \
                "trace=True needs a Recorder observer to collect events"
            recorder = observer
        t_step0 = time.perf_counter()
        clock = lambda: time.perf_counter() - t_step0  # noqa: E731
        # In-flight transfer tracking with the spec's overlap-depth cap:
        # real copies (device_put and store moves) are async, so the
        # runtime is what makes the live HBM bound hold — at most
        # ``depth`` moves may be outstanding per channel before the
        # oldest is retired (blocked on). Same channel vocabulary the
        # simulator prices (docs/transfer.md) — and the same observer:
        # each real copy retires as a channel-track span.
        xfers = AsyncTransferRuntime(self.spec.depth, observer=observer,
                                     clock=clock)

        def chan(op: str, i: int) -> Optional[tuple]:
            pol = respol.RELEASE_OPS.get(op) or respol.RESTORE_OPS[op]
            return channel_key(pol.mechanism, i, partner.get(i),
                               release=op in respol.RELEASE_OPS)

        # Slice each microbatch once, not once per (chunk, F) — interleaving
        # visits every microbatch p*v times on this hot path.
        micros = [
            {k: val[j * self.b:(j + 1) * self.b] for k, val in batch.items()}
            for j in range(m)]

        # act_in/grad_in are keyed by the *virtual* stage they feed (plus
        # the sequence slice — 0 for unsliced schedules): the output of
        # virtual stage vs routes to vs+1, which lives on device
        # (vs+1) % p — possibly the same device, next chunk.
        act_in: Dict[Tuple[int, int, int], Any] = {}
        grad_in: Dict[Tuple[int, int, int], Any] = {}
        losses: Dict[Tuple[int, int], jnp.ndarray] = {}
        grads: List[Any] = [None] * nv
        dummy = (jnp.zeros((self.b, Ls, cfg.d_model), jnp.dtype(cfg.dtype)),
                 jnp.zeros((), jnp.float32))
        scale = jnp.float32(1.0 / m)

        if sliced:
            # Per-(mb, slice) inputs: the slice's token window plus its
            # global start position (the stage fn derives positions and
            # the causal mask against the retained-KV prefix from it).
            micros_sl = {
                (j, s): {**{k: val[:, s * Ls:(s + 1) * Ls]
                            for k, val in micros[j].items()},
                         "offset": jnp.int32(s * Ls)}
                for j in range(m) for s in range(c)}
            # The sliced last stage returns UN-normalized nll sums; the
            # whole-microbatch valid-token count normalizes them so the
            # summed slice losses equal the unchunked stage loss.
            cnt = [jnp.maximum(jnp.sum(
                (micros[j]["labels"] >= 0).astype(jnp.float32)), 1.0)
                for j in range(m)]
            dt = jnp.dtype(cfg.dtype)
            nkv, hd = cfg.num_kv_heads, cfg.head_dim
            kv_zero = [tuple((jnp.zeros((self.b, 0, nkv, hd), dt),
                              jnp.zeros((self.b, 0, nkv, hd), dt))
                             for _ in self.splitter.assign[vs])
                       for vs in range(nv)]
            # (vs, mb, sl) -> pending dKV cotangent: prefix gradients the
            # LATER slices' backwards (which run first — reverse slice
            # order) have emitted for slice sl's own KV.
            dkv_acc: Dict[Tuple[int, int, int], Any] = {}

        def kv_prefix_for(i, vs, mb, chunk, sl):
            """Concatenate earlier slices' retained KV (slice order =
            global position order), reading through ``store.peek`` so
            the prefix is reachable wherever a residency policy moved
            the earlier units (partner store, host, dropped)."""
            if sl == 0:
                return kv_zero[vs]
            parts = [store.peek(i, mb, chunk, j)[-1] for j in range(sl)]
            return tuple(
                (jnp.concatenate([part[li][0] for part in parts], axis=1),
                 jnp.concatenate([part[li][1] for part in parts], axis=1))
                for li in range(len(kv_zero[vs])))

        def wrap(body):
            """Shared post-instruction bookkeeping: span emission through
            the attached observer (blocking so the span covers real
            device time, not async dispatch) and the live stash-cap
            assertion."""
            def handler(i, ins):
                t0 = time.perf_counter() if observer is not None else 0.0
                sync = body(i, ins)
                if sync is P.BLOCKED:
                    return P.BLOCKED
                if observer is not None:
                    if sync is not None:
                        jax.block_until_ready(sync)
                    observer.emit(
                        ins.op, i, ins.mb, ins.chunk, ins.sl, ins.phase,
                        t0 - t_step0, time.perf_counter() - t_step0,
                        hbm=store.resident_bytes(i))
                if self.enforce_cap and self.cap is not None:
                    # swap ops (EVICT/LOAD) also touch the partner's
                    # store — check both ends so acceptor-side transients
                    # can't hide behind the acceptor's next pop.
                    for dev in ((i, partner[i])
                                if ins.op in swap_ops else (i,)):
                        assert store.held(dev) <= bounds[dev], \
                            (dev, ins, store.held(dev), bounds[dev])
                return None
            return handler

        def on_f(i, ins):
            vs = ins.vs
            # pop: the boundary activation has exactly one consumer;
            # holding it past this F would overhang the stash accounting
            # the cap is asserted on.
            carry = dummy if vs == 0 else act_in.pop((vs, ins.mb, ins.sl),
                                                     None)
            if carry is None:
                return P.BLOCKED
            if not sliced:
                out, vjp_fn = jax.vjp(
                    self.stage_fns[vs], stage_params[vs], carry,
                    micros[ins.mb])
                # recompute residency keeps the boundary input alongside
                # the residuals: DROP strips to it, RECOMPUTE re-forwards
                # from it
                store.put(i, ins.mb,
                          (vjp_fn, carry) if is_recompute else vjp_fn,
                          ins.chunk)
                if vs == nv - 1:
                    losses[(ins.mb, 0)] = out
                else:
                    act_in[(vs + 1, ins.mb, 0)] = out
                return out
            sl = ins.sl
            kv_prefix = kv_prefix_for(i, vs, ins.mb, ins.chunk, sl)
            (primary, kv_own), vjp_fn = jax.vjp(
                self.stage_fns[vs], stage_params[vs], carry, kv_prefix,
                micros_sl[(ins.mb, sl)])
            # the slice's own KV rides in the stash entry (last element)
            # so later slices' forwards — and the residency machinery —
            # see ONE unit, not a separate KV cache
            store.put(i, ins.mb,
                      (vjp_fn, carry, kv_own) if is_recompute
                      else (vjp_fn, kv_own), ins.chunk, sl)
            if vs == nv - 1:
                nll_sum, aux = primary
                losses[(ins.mb, sl)] = nll_sum / cnt[ins.mb] + aux
            else:
                act_in[(vs + 1, ins.mb, sl)] = primary
            return primary

        def on_b(i, ins):
            vs = ins.vs
            if vs == nv - 1:
                cot = (scale / cnt[ins.mb], scale) if sliced else scale
            else:
                cot = grad_in.pop((vs, ins.mb, ins.sl), None)
                if cot is None:
                    return P.BLOCKED
            entry = store.pop(i, ins.mb, ins.chunk, ins.sl)
            if not sliced:
                vjp_fn = entry[0] if is_recompute else entry
                d_sp, d_carry, _ = vjp_fn(cot)
            else:
                sl = ins.sl
                vjp_fn, kv_own = entry[0], entry[-1]
                # dKV for this slice's own KV: what LATER slices'
                # backwards (already run — reverse slice order) emitted
                cot_kv = dkv_acc.pop((vs, ins.mb, sl), None)
                if cot_kv is None:       # newest slice: nothing pending
                    cot_kv = jax.tree.map(jnp.zeros_like, kv_own)
                d_sp, d_carry, d_kvp, _ = vjp_fn((cot, cot_kv))
                for j in range(sl):      # scatter prefix grads backward
                    seg = tuple((dk[:, j * Ls:(j + 1) * Ls],
                                 dv[:, j * Ls:(j + 1) * Ls])
                                for dk, dv in d_kvp)
                    prev = dkv_acc.get((vs, ins.mb, j))
                    dkv_acc[(vs, ins.mb, j)] = seg if prev is None \
                        else jax.tree.map(jnp.add, prev, seg)
            grads[vs] = d_sp if grads[vs] is None else jax.tree.map(
                jnp.add, grads[vs], d_sp)
            if vs > 0:
                grad_in[(vs - 1, ins.mb, ins.sl)] = d_carry
            return (d_sp, d_carry)

        # Every move handler follows the compiled ISSUE/WAIT contract:
        # the ISSUE half starts the (async) copy and registers it with
        # the transfer runtime; the WAIT half blocks on the channel up to
        # that unit, so the dependent compute touches the data only once
        # the copy is really complete — and the depth cap is enforced at
        # submit time.
        def on_evict(i, ins):
            if ins.is_wait:
                return xfers.wait(chan(ins.op, i), ins.done_key)
            return xfers.submit(
                chan(ins.op, i), ins.done_key,
                lambda: store.evict(i, ins.mb, partner[i], ins.chunk,
                                    ins.sl))

        def on_load(i, ins):
            if ins.is_wait:
                return xfers.wait(chan(ins.op, i), ins.done_key)
            return xfers.submit(
                chan(ins.op, i), ins.done_key,
                lambda: store.load(i, ins.mb, partner[i], ins.chunk,
                                   ins.sl))

        def on_offload(i, ins):
            if ins.is_wait:
                return xfers.wait(chan(ins.op, i), ins.done_key)
            # real D2H: the vjp closure is a tree_util.Partial pytree, so
            # device_put moves the residual arrays to the host platform
            return xfers.submit(
                chan(ins.op, i), ins.done_key,
                lambda: store.offload(i, ins.mb, ins.chunk, ins.sl,
                                      mover=mem_offload.to_host))

        def on_fetch(i, ins):
            if ins.is_wait:
                return xfers.wait(chan(ins.op, i), ins.done_key)
            return xfers.submit(
                chan(ins.op, i), ins.done_key,
                lambda: store.fetch(i, ins.mb, ins.chunk, ins.sl,
                                    mover=mem_offload.to_device))

        def on_drop(i, ins):
            if ins.is_wait:
                return None
            # free the residuals (the vjp closure reference), keep the
            # boundary input the re-forward starts from — plus, under
            # slicing, the slice's own KV (later slices peek at it)
            strip = (lambda e: (e[1], e[2])) if sliced else (lambda e: e[1])
            store.drop(i, ins.mb, ins.chunk, ins.sl, strip=strip)

        def on_recompute(i, ins):
            if ins.is_wait:
                return None
            vs = ins.vs
            kept = store.dropped_input(i, ins.mb, ins.chunk, ins.sl)
            if not sliced:
                carry = kept
                out, vjp_fn = jax.vjp(
                    self.stage_fns[vs], stage_params[vs], carry,
                    micros[ins.mb])
                store.recompute(i, ins.mb, (vjp_fn, carry), ins.chunk)
                return out
            carry = kept[0]
            kv_prefix = kv_prefix_for(i, vs, ins.mb, ins.chunk, ins.sl)
            (primary, kv_own), vjp_fn = jax.vjp(
                self.stage_fns[vs], stage_params[vs], carry, kv_prefix,
                micros_sl[(ins.mb, ins.sl)])
            store.recompute(i, ins.mb, (vjp_fn, carry, kv_own), ins.chunk,
                            ins.sl)
            return primary

        # Handlers by registered policy mechanism (like the simulator's
        # pricing set): a plugin policy's ops are executable without
        # edits here — the registry IS the op set.
        mech_release = {"swap": on_evict, "host": on_offload,
                        "recompute": on_drop}
        mech_restore = {"swap": on_load, "host": on_fetch,
                        "recompute": on_recompute}
        handlers = {F: wrap(on_f), B: wrap(on_b)}
        for op, pol in respol.RELEASE_OPS.items():
            handlers[op] = wrap(mech_release[pol.mechanism])
        for op, pol in respol.RESTORE_OPS.items():
            handlers[op] = wrap(mech_restore[pol.mechanism])
        P.run(schedule.streams, handlers, observer=observer, dep_gated=True)
        xfers.drain()                       # no copy escapes the step

        loss = sum(losses.values()) * scale
        full_grads = self.splitter.merge(grads)
        stats = store.stats()
        stats.transfers_inflight_peak = xfers.inflight_peak
        return StepResult(loss=loss, grads=full_grads, stats=stats,
                          events=list(recorder.spans)
                          if recorder is not None else None)
