"""Executable pipeline runtime: a schedule interpreter with true 1F1B /
BPipe activation-stash semantics.

This is the Megatron-equivalent layer of the reproduction: schedules from
``core.schedule`` are interpreted instruction-by-instruction; each F runs
``jax.vjp`` on its stage (so the stash — the vjp residuals — is *really*
held until the matching B), EVICT/LOAD move stash entries between the
evictor's and acceptor's stores (on one host this is bookkeeping plus the
byte accounting from ``core.memory_model``; on a multi-device host it
would be a device_put), and every B consumes its stash and propagates the
cotangent upstream.

Numerical contract (tested): for any schedule kind,
    executor.step(params, batch).loss == models.loss_fn(params, batch)
and gradients match to fp32 tolerance. BPipe's cap
``ceil((p+2)/2)`` is asserted on the live store, not on paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import memory_model as mm
from repro.core import schedule as sched
from repro.core.notation import Notation
from repro.core.schedule import B, EVICT, F, LOAD
from repro.pipeline import stage as stage_mod


@dataclasses.dataclass
class StoreStats:
    peak_local: Dict[int, int]
    peak_bytes: Dict[int, float]
    evictions: int
    loads: int
    bytes_moved: float


class ActivationStore:
    """Per-stage stash of vjp closures, with BPipe eviction accounting."""

    def __init__(self, p: int, bytes_per_stash: float):
        self.p = p
        self.bytes_per_stash = bytes_per_stash
        self.local: List[Dict[int, Any]] = [dict() for _ in range(p)]
        self.foreign: List[Dict[int, Any]] = [dict() for _ in range(p)]
        self.peak: Dict[int, int] = {i: 0 for i in range(p)}
        self.evictions = 0
        self.loads = 0
        self.bytes_moved = 0.0

    def _bump(self, i):
        n = len(self.local[i]) + len(self.foreign[i])
        self.peak[i] = max(self.peak[i], n)

    def put(self, i, mb, stash):
        assert mb not in self.local[i]
        self.local[i][mb] = stash
        self._bump(i)

    def pop(self, i, mb):
        return self.local[i].pop(mb)

    def evict(self, i, mb, partner):
        stash = self.local[i].pop(mb)
        self.foreign[partner][(i, mb)] = stash
        self.evictions += 1
        self.bytes_moved += self.bytes_per_stash
        self._bump(partner)

    def load(self, i, mb, partner):
        stash = self.foreign[partner].pop((i, mb))
        self.local[i][mb] = stash
        self.loads += 1
        self.bytes_moved += self.bytes_per_stash
        self._bump(i)

    def stats(self) -> StoreStats:
        return StoreStats(
            peak_local=dict(self.peak),
            peak_bytes={i: n * self.bytes_per_stash for i, n in self.peak.items()},
            evictions=self.evictions, loads=self.loads,
            bytes_moved=self.bytes_moved)


@dataclasses.dataclass
class StepResult:
    loss: jnp.ndarray
    grads: Any
    stats: StoreStats


class PipelineExecutor:
    """Interprets a pipeline schedule over a real model.

    Args:
      cfg: model config (any assigned architecture).
      p: number of pipeline stages (must be <= num_layers).
      kind: 'gpipe' | '1f1b' | 'bpipe'.
      micro_batch: rows per microbatch (global batch must divide evenly).
      notation: optional paper-notation override for byte accounting.
    """

    def __init__(self, cfg: ModelConfig, p: int, kind: str = "1f1b",
                 micro_batch: int = 1, remat: str = "none",
                 notation: Optional[Notation] = None, enforce_cap: bool = True):
        assert p <= cfg.num_layers
        self.cfg, self.p, self.kind = cfg, p, kind
        self.b = micro_batch
        self.remat = remat
        self.enforce_cap = enforce_cap
        self.stage_fns = [stage_mod.make_stage_fn(cfg, p, i, remat) for i in range(p)]
        self.partner = {}
        for a, c in sched.bpipe_pairs(p):
            self.partner[a] = c
            self.partner[c] = a
        self.notation = notation

    # ------------------------------------------------------------------
    def step(self, params, batch) -> StepResult:
        cfg, p = self.cfg, self.p
        bsz = batch["tokens"].shape[0]
        assert bsz % self.b == 0
        m = bsz // self.b
        seq = batch["tokens"].shape[1]
        n = self.notation or Notation(
            a=cfg.num_heads, b=self.b, h=cfg.d_model, l=cfg.num_layers,
            s=seq, v=cfg.vocab_size, B=bsz, p=p, t=1)
        attention = {"none": "none", "attn": "recompute", "full": "recompute",
                     "flash": "flash"}.get(self.remat, "none")
        store = ActivationStore(p, mm.act_bytes_per_stage(n, attention))

        stage_params = stage_mod.split_params(params, cfg, p)
        streams = sched.build(self.kind, p, m)
        cap = sched.bpipe_cap(p)

        def micro(mb):
            sl = slice(mb * self.b, (mb + 1) * self.b)
            return {k: v[sl] for k, v in batch.items()}

        act_in: Dict[tuple, Any] = {}
        grad_in: Dict[tuple, Any] = {}
        losses: Dict[int, jnp.ndarray] = {}
        grads: List[Any] = [None] * p
        dummy = jnp.zeros((self.b, seq, cfg.d_model),
                          jnp.dtype(cfg.dtype))

        idx = {i: 0 for i in range(p)}
        remaining = sum(len(s) for s in streams.values())
        scale = jnp.float32(1.0 / m)
        while remaining:
            progressed = False
            for i in range(p):
                while idx[i] < len(streams[i]):
                    ins = streams[i][idx[i]]
                    if ins.op == F:
                        carry = ((dummy, jnp.zeros((), jnp.float32)) if i == 0
                                 else act_in.get((i, ins.mb)))
                        if carry is None:
                            break
                        mb_batch = micro(ins.mb)
                        fn = self.stage_fns[i]
                        out, vjp_fn = jax.vjp(
                            lambda sp, c: fn(sp, c, mb_batch),
                            stage_params[i], carry)
                        store.put(i, ins.mb, vjp_fn)
                        if i == p - 1:
                            losses[ins.mb] = out
                        else:
                            act_in[(i + 1, ins.mb)] = out
                    elif ins.op == B:
                        if i == p - 1:
                            cot = scale
                        else:
                            cot = grad_in.get((i, ins.mb))
                            if cot is None:
                                break
                        vjp_fn = store.pop(i, ins.mb)
                        d_sp, d_carry = vjp_fn(cot)
                        grads[i] = d_sp if grads[i] is None else jax.tree.map(
                            jnp.add, grads[i], d_sp)
                        if i > 0:
                            grad_in[(i - 1, ins.mb)] = d_carry
                    elif ins.op == EVICT:
                        store.evict(i, ins.mb, self.partner[i])
                    else:  # LOAD
                        store.load(i, ins.mb, self.partner[i])
                    if self.enforce_cap and self.kind == "bpipe":
                        held = len(store.local[i]) + len(store.foreign[i])
                        assert held <= cap, (i, ins, held, cap)
                    idx[i] += 1
                    remaining -= 1
                    progressed = True
            assert progressed, "pipeline deadlock"

        loss = sum(losses.values()) * scale
        full_grads = stage_mod.merge_stage_grads(grads, cfg, p, params)
        return StepResult(loss=loss, grads=full_grads, stats=store.stats())
