"""repro: a multi-pod JAX training framework reproducing and extending
"Re-evaluating the Memory-balanced Pipeline Parallelism: BPipe"
(Huang et al., Meituan 2024)."""
__version__ = "0.1.0"
