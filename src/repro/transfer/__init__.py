"""``repro.transfer``: the per-device transfer engine for activation
residency moves.

Residency moves (EVICT/LOAD, OFFLOAD/FETCH, plugin policies) ride
explicit *channels* — the BPipe pair link, the D2H and H2D halves of the
host link — with an issue-early/complete-lazy contract: a move's ISSUE
half starts the copy as soon as its dependency is ready, its WAIT half
blocks the dependent compute only when the data is actually needed, and
each channel admits a bounded number of in-flight transfers
(``ScheduleSpec.depth``). Overlap falls out of channel-queue occupancy
instead of hand-rolled per-op special cases (docs/transfer.md).

Layers:
  * ``channel``  — channel keys + the serialized FIFO pricing model
    (pure Python; no jax). Shared vocabulary between the simulator and
    the executor.
  * ``engine``   — ``TransferEngine``: the simulator-facing channel set
    for one compiled ``plan.Schedule``; prices every registered
    residency policy's moves by mechanism.
  * ``runtime``  — ``AsyncTransferRuntime``: the executor-facing side;
    tracks real async ``jax.device_put`` copies per channel and enforces
    the in-flight depth cap so live HBM bounds stay enforced. Imported
    lazily by the executor (keeps this package jax-free for the
    simulator).
"""
from repro.transfer.channel import (D2H, H2D, PEER, Channel, ChannelStats,
                                    channel_key)
from repro.transfer.engine import TransferEngine

__all__ = ["Channel", "ChannelStats", "TransferEngine", "channel_key",
           "PEER", "D2H", "H2D"]
