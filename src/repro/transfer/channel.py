"""Transfer channels: the directional links residency moves ride.

A channel is one serialized link endpoint:

  * ``(PEER, a, b)`` — the evictor<->acceptor pair link (NVLink / 1-hop
    ICI). EVICT and LOAD of a pair share it in both directions — the
    paper's §4 overlap argument is about exactly this link keeping up
    with two moves per F+B slot, which is why it is modeled
    half-duplex-shared (the pinned ``(Tf+Tb)/(2v)`` stall threshold
    falls out of that sharing).
  * ``(D2H, i)`` / ``(H2D, i)`` — the two directions of device ``i``'s
    host link (PCIe-class). Direction-split: offload traffic does not
    contend with fetch traffic.

``Channel`` is the pricing model the simulator uses: transfers are
serialized FIFO in issue order, each occupying the link for its
transfer time; occupancy statistics (how many transfers were in flight
— issued but not complete — at once) report how much overlap a schedule
actually achieved. ``channel_key`` is shared with the executor's
``runtime`` so both sides agree on what contends with what.

Recompute-mechanism policies have no channel (their restore bill is
FLOPs on the compute frontier, not bytes on a link): ``channel_key``
returns ``None`` for them.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Tuple

#: Channel kinds. PEER is the evictor<->acceptor pair link; D2H/H2D are
#: the two directions of a device's host link.
PEER, D2H, H2D = "peer", "d2h", "h2d"

ChannelKey = Tuple


def channel_key(mechanism: str, stage: int, partner: Optional[int] = None,
                release: bool = True) -> Optional[ChannelKey]:
    """The channel a residency move of ``mechanism`` issued by ``stage``
    rides: the shared pair link for the swap, the release (D2H) or
    restore (H2D) half of the host link for offload, ``None`` when the
    mechanism moves no bytes (recompute, none)."""
    if mechanism == "swap":
        assert partner is not None, stage
        return (PEER, min(stage, partner), max(stage, partner))
    if mechanism == "host":
        return (D2H if release else H2D, stage)
    return None


@dataclasses.dataclass
class ChannelStats:
    """Occupancy accounting for one channel over a simulated step."""
    key: ChannelKey
    moves: int = 0           # transfers issued
    busy: float = 0.0        # summed transfer (link-occupancy) time
    queue_peak: int = 0      # max transfers in flight at one instant
    stall: float = 0.0       # summed data-ready-but-link-busy wait

    def utilization(self, makespan: float) -> float:
        return self.busy / makespan if makespan > 0 else 0.0


class Channel:
    """One serialized link: FIFO transfer pricing plus in-flight
    occupancy tracking.

    ``issue(ready)`` prices one transfer whose input data is available
    at time ``ready``: it starts when both the data and the link are
    ready and occupies the link for ``t_move``. Transfers are processed
    in issue order (each stage issues its own moves in stream order, so
    for single-issuer channels — every built-in policy at default caps —
    the FIFO order is deterministic regardless of engine dispatch
    order).

    ``depth`` is the bounded-admission half of the issue-early
    contract: transfer k may not be *issued* (its source buffer pinned)
    before the (k - depth)-th prior transfer completed — the same cap
    the executor's ``AsyncTransferRuntime`` enforces on real copies and
    ``memory_model`` charges, so ``queue_peak`` (in-flight transfers,
    issue to completion) never exceeds ``depth``. Because the link
    itself serializes, the admission delay provably cannot change
    start/end times: ``start = max(ready, free)`` and ``free`` is the
    last completion, which is >= every earlier one — deeper overlap is
    therefore priced purely through the issue-early window the
    simulator widens by ``spec.depth`` slots before calling ``issue``.
    """

    def __init__(self, key: ChannelKey, t_move: float, depth: int = 1):
        assert depth >= 1, depth
        self.key = key
        self.t_move = float(t_move)
        self.depth = depth
        self.free = 0.0
        self._ends: List[float] = []          # completion times, ascending
        self.stats = ChannelStats(key)

    def issue(self, ready: float) -> Tuple[float, float]:
        """Price one transfer: returns ``(start, end)``."""
        data_ready = ready
        # bounded admission: wait for a free in-flight slot (no effect
        # on start/end — see the class docstring — only on occupancy)
        if len(self._ends) >= self.depth:
            ready = max(ready, self._ends[-self.depth])
        start = max(ready, self.free)
        end = start + self.t_move
        # in flight at issue time: this transfer plus every earlier one
        # not yet complete when this one was admitted. _ends is
        # ascending (each end >= the previous channel-free time), so the
        # count is a bisect, not a scan — the planner prices O(m) moves
        # per channel per candidate.
        pending = len(self._ends) - bisect.bisect_right(self._ends,
                                                        ready) + 1
        self._ends.append(end)
        st = self.stats
        st.moves += 1
        st.busy += self.t_move
        st.stall += start - data_ready
        st.queue_peak = max(st.queue_peak, pending)
        self.free = end
        return start, end
