"""``AsyncTransferRuntime``: the executor-facing half of the transfer
engine.

``jax.device_put`` (and same-host store moves) are *async*: the call
returns before the copy completes, and the arrays block only when read.
That is exactly the issue-early/complete-lazy contract — but unbounded
in-flight copies would pin unbounded source buffers, so live HBM bounds
would only hold on paper. This runtime tracks every in-flight move per
channel (the same ``channel_key`` vocabulary the simulator prices) and
enforces the spec's overlap ``depth``: submitting a move while ``depth``
transfers are already in flight on that channel blocks on the oldest
(``jax.block_until_ready``) before admitting the new one.

The executor's WAIT halves call ``wait`` with the move's unit key; the
runtime retires FIFO up to and including that unit, so the dependent
compute touches the data only after the copy is really complete.
``drain()`` at step end retires everything (no copy escapes the step).
"""
from __future__ import annotations

import collections
from typing import Any, Deque, Dict, Hashable, Optional, Tuple

from repro.transfer.channel import ChannelKey


def _block(payload: Any) -> Any:
    """Block until a pytree's arrays are materialized (non-array leaves —
    e.g. the callables inside a vjp ``Partial`` — pass through)."""
    import jax
    return jax.block_until_ready(payload)


class AsyncTransferRuntime:
    """Bounded-depth in-flight tracking over real async copies.

    ``observer`` (the duck-typed ``repro.obs`` contract) plus ``clock``
    (a zero-arg step-relative timer) turn every real move into a
    channel-track span — submit time to retire (block) time, the same
    occupancy interval the simulator's ``Channel`` prices — keyed by the
    move's unit key (``PlannedInstr.done_key``: (op, stage, mb, chunk,
    sl))."""

    def __init__(self, depth: int = 1, observer=None, clock=None):
        self.depth = max(1, int(depth))
        self._q: Dict[ChannelKey, Deque[Tuple[Hashable, Any, float]]] = {}
        self.submitted = 0
        self.retired = 0
        self.inflight_peak = 0       # max in-flight on any one channel
        self.observer = observer
        self.clock = clock if clock is not None else (lambda: 0.0)

    def submit(self, key: Optional[ChannelKey], unit: Hashable,
               launch: Any) -> Any:
        """Issue one move: reserve a channel slot, then call ``launch``
        (the thunk that starts the async copy — a store move wrapping
        ``jax.device_put``) and track its payload. The slot is reserved
        *before* the copy starts — the oldest in-flight move is retired
        (blocked on) first — so at most ``depth`` copies are ever
        concurrently in flight per channel, exactly what
        ``memory_model`` budgets. ``key=None`` (channel-less
        mechanisms) just runs the thunk."""
        if key is None:
            return launch()
        q = self._q.setdefault(key, collections.deque())
        while len(q) >= self.depth:   # depth cap: reserve the slot first
            self._retire(key, q.popleft())
        payload = launch()
        q.append((unit, payload, self.clock()))
        self.submitted += 1
        self.inflight_peak = max(self.inflight_peak, len(q))
        return payload

    def wait(self, key: Optional[ChannelKey], unit: Hashable) -> None:
        """Complete-lazy barrier: block until ``unit``'s move (and every
        earlier move on the channel — FIFO) is done. A unit the depth
        cap already retired is a no-op — blocking on *newer* unrelated
        transfers would serialize exactly the overlap the depth knob
        buys."""
        if key is None:
            return
        q = self._q.get(key)
        if not q or not any(u == unit for u, _, _ in q):
            return
        while q:
            item = q.popleft()
            self._retire(key, item)
            if item[0] == unit:
                break

    def drain(self) -> None:
        """Retire every in-flight move (step barrier)."""
        for key, q in self._q.items():
            while q:
                self._retire(key, q.popleft())

    def _retire(self, key: ChannelKey,
                item: Tuple[Hashable, Any, float]) -> None:
        unit, payload, t_submit = item
        _block(payload)
        self.retired += 1
        if self.observer is not None:
            op, stage, mb, chunk, sl = unit
            self.observer.emit(op, stage, mb, chunk, sl, "",
                               t_submit, self.clock(), track="channel",
                               channel=key)
