"""``TransferEngine``: the simulator-facing channel set for one compiled
schedule.

Built per simulated step from a ``plan.Schedule`` plus per-channel-kind
transfer times, it maps every registered residency policy's moves onto
channels by mechanism (swap -> the pair link, host -> the D2H/H2D
halves; recompute -> no channel) and prices them through the serialized
FIFO model in ``repro.transfer.channel``. The simulator's handlers stop
owning link bookkeeping: they ask the engine to issue and read back
``(start, end)``.

A policy registered by a plugin (``repro.memory.policy.register``) is
routed here with no engine edits — the mechanism field is the whole
contract.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.memory.policy import ResidencyPolicy
from repro.transfer.channel import (D2H, H2D, PEER, Channel, ChannelKey,
                                    ChannelStats, channel_key)


class TransferEngine:
    """Per-device directional channels for one compiled ``Schedule``.

    ``depth`` is the bounded-admission cap every channel applies (see
    ``channel.Channel`` — it bounds occupancy, provably not completion
    times); the matching issue-early *window* is the simulator's knob
    (it widens the restore issue time by ``spec.depth`` slots before
    calling ``issue``), and the executor runtime enforces the same cap
    on real copies."""

    def __init__(self, schedule, *, t_peer: float = 0.0, t_d2h: float = 0.0,
                 t_h2d: float = 0.0, depth: int = 1, observer=None):
        self.schedule = schedule
        self.depth = max(1, int(depth))
        self._t = {PEER: t_peer, D2H: t_d2h, H2D: t_h2d}
        self.channels: Dict[ChannelKey, Channel] = {}
        # duck-typed repro.obs Observer: every priced move additionally
        # emits a channel-track span (enqueue -> dequeue) when attached
        self.observer = observer

    def key_for(self, pol: ResidencyPolicy, stage: int,
                release: bool) -> Optional[ChannelKey]:
        return channel_key(pol.mechanism, stage,
                           self.schedule.partner.get(stage), release)

    def channel_for(self, pol: ResidencyPolicy, stage: int,
                    release: bool) -> Optional[Channel]:
        key = self.key_for(pol, stage, release)
        if key is None:
            return None
        ch = self.channels.get(key)
        if ch is None:
            ch = self.channels[key] = Channel(key, self._t[key[0]],
                                              self.depth)
        return ch

    def issue(self, pol: ResidencyPolicy, stage: int, ready: float,
              release: bool, ins=None) -> Tuple[float, float]:
        """Issue one move on the policy's channel; returns ``(start,
        end)``. A channel-less mechanism (recompute's DROP) completes
        instantly at ``ready`` — its restore bill is the caller's.
        ``ins`` (the issuing ``PlannedInstr``) lets an attached observer
        label the channel-occupancy span it emits per move."""
        ch = self.channel_for(pol, stage, release)
        if ch is None:
            return ready, ready
        start, end = ch.issue(ready)
        if self.observer is not None and ins is not None:
            self.observer.emit(ins.op, stage, ins.mb, ins.chunk, ins.sl,
                               ins.phase, start, end, track="channel",
                               channel=ch.key)
        return start, end

    def stats(self) -> Dict[ChannelKey, ChannelStats]:
        return {key: ch.stats for key, ch in self.channels.items()}

    @property
    def queue_peak(self) -> int:
        """Max in-flight transfers reached on any channel."""
        return max((ch.stats.queue_peak for ch in self.channels.values()),
                   default=0)
