"""Flat-npz pytree checkpointing with path-keyed entries.

No orbax in this container; this is a self-contained, restartable format:
leaves are saved under their tree paths, restored against a template
(shape/dtype checked), so params + AdamState round-trip exactly.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np


import ml_dtypes

# dtypes numpy can't serialize natively: stored as bit-equal uint views
_VIEW = {np.dtype(ml_dtypes.bfloat16): np.dtype(np.uint16)}
_UNVIEW = {v: k for k, v in _VIEW.items()}


def _key(path) -> str:
    return "/".join(
        str(getattr(e, "key", getattr(e, "name", getattr(e, "idx", e))))
        for e in path)


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype in _VIEW:
            out["__view__/" + _key(path)] = arr.view(_VIEW[arr.dtype])
        else:
            out[_key(path)] = arr
    return out


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, template: Any) -> Any:
    with np.load(path) as data:
        flat = dict(data)
    leaves, _ = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, leaf in leaves:
        key = _key(p)
        want = np.asarray(leaf).dtype
        if key in flat:
            arr = flat[key]
        elif "__view__/" + key in flat:
            arr = flat["__view__/" + key]
            arr = arr.view(_UNVIEW.get(arr.dtype, arr.dtype))
        else:
            raise KeyError(f"checkpoint missing {key}")
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        if arr.dtype != want:
            arr = arr.astype(want)
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
