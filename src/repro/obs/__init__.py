"""repro.obs — one event stream out of ``plan.run``.

Structured observability for every engine that dispatches a compiled
schedule (docs/observability.md):

  * ``events``   — the canonical ``Span`` schema, the ``Observer``
                   contract, and ``Recorder`` (the ONLY module that
                   constructs trace spans — scripts/check.sh enforces it)
  * ``timeline`` — the ordered per-stage / per-channel view
  * ``metrics``  — bubble fractions, stalls, channel occupancy, MFU,
                   HBM-residency timelines
  * ``export``   — the unified Perfetto/Chrome exporter (lossless
                   round trip)
  * ``compare``  — sim-vs-real divergence audits
"""
from repro.obs.events import (CHANNEL, COMPUTE, ISSUE, WAIT, Observer,
                              Recorder, Span)
from repro.obs.timeline import Timeline

__all__ = ["CHANNEL", "COMPUTE", "ISSUE", "WAIT", "Observer", "Recorder",
           "Span", "Timeline"]
