"""The ONE event schema and observer seam for everything ``plan.run``
dispatches (docs/observability.md).

The paper's §4 estimation method is only auditable if the simulator and
the real executor describe their work in the same vocabulary. This
module is that vocabulary — and, by the repo invariant enforced in
``scripts/check.sh``, the ONLY module that constructs trace spans:

  * ``Span`` — one timed event, keyed ``(op, stage, mb, chunk, sl,
    phase)``: exactly a compiled ``PlannedInstr``'s identity (including
    the ISSUE/WAIT halves of residency moves) plus ``start``/``end``
    in the emitter's clock (simulated time units for the simulator,
    wall-clock seconds for the executor). Channel occupancy rides the
    same schema on ``track="channel"`` with the transfer-channel key
    attached; real HBM residency rides along as the optional ``hbm``
    sample the executor reads off its ``ActivationStore``.
  * ``Observer`` — the contract the engines call: ``dispatch`` fires on
    every instruction the ready-loop retires (engine order — what
    ``obs.compare`` audits for ordering divergence), ``span`` receives
    every timed span, ``counter`` receives named counter samples.
    ``Observer.emit(...)`` is the single span-construction helper the
    simulator, executor, and transfer engine call — no other module
    builds a ``Span``.
  * ``Recorder`` — the collecting observer: spans + dispatch order +
    counters, with the small derived views (makespan, per-stage order)
    the metrics/timeline/export/compare layers build on.

Everything is zero-cost when no observer is attached: the engines guard
every emission with ``if observer is not None`` and otherwise run the
exact pre-instrumentation code path (golden-pinned).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

#: Move phases, shared with the compiled-plan IR (``plan.ISSUE`` /
#: ``plan.WAIT``): redeclared here (and asserted equal in tests) so the
#: event schema has no import edge back into the engine.
ISSUE, WAIT = "issue", "wait"

#: Span tracks: per-stage compute/move instructions vs. transfer-channel
#: occupancy intervals.
COMPUTE, CHANNEL = "compute", "channel"

#: The span identity tuple: (op, stage, mb, chunk, sl, phase).
SpanKey = Tuple[str, int, int, int, int, str]


@dataclasses.dataclass(frozen=True)
class Span:
    """One timed schedule event in the canonical schema.

    ``op``/``stage``/``mb``/``chunk``/``sl``/``phase`` are structured
    fields — the ``.sN`` / ``+w`` suffixes earlier trace paths folded
    into op strings (and lost on round trip) are presentation only
    (``label``). ``track`` separates stage instructions from channel
    occupancy; channel spans carry the transfer-channel ``channel`` key
    (``repro.transfer.channel.channel_key`` vocabulary). ``hbm`` is the
    emitter's device-resident byte sample at ``end`` when it has one
    (the executor reads its store; the simulator leaves it None and
    ``obs.metrics.hbm_timeline`` reconstructs the counter from byte
    weights)."""
    op: str
    stage: int
    mb: int
    chunk: int = 0
    sl: int = 0
    phase: str = ""                       # "", ISSUE or WAIT
    start: float = 0.0
    end: float = 0.0
    track: str = COMPUTE
    channel: Optional[Tuple] = None       # channel key for channel spans
    hbm: Optional[float] = None           # stage-resident bytes at `end`

    @property
    def key(self) -> SpanKey:
        return (self.op, self.stage, self.mb, self.chunk, self.sl,
                self.phase)

    @property
    def duration(self) -> float:
        return self.end - self.start

    @property
    def is_wait(self) -> bool:
        return self.phase == WAIT

    @property
    def canonical(self) -> bool:
        """Does this span represent the event itself (not its completion
        barrier)? Canonical spans are what calibration medians and
        per-op counts bin over — one per instruction."""
        return self.phase != WAIT and self.track == COMPUTE

    @property
    def label(self) -> str:
        """Presentation label, matching ``PlannedInstr.__repr__``:
        ``EVICT3.c1.s2+w``. Purely derived — nothing parses it back."""
        c = f".c{self.chunk}" if self.chunk else ""
        s = f".s{self.sl}" if self.sl else ""
        w = "+w" if self.phase == WAIT else ""
        return f"{self.op}{self.mb}{c}{s}{w}"

    def to_args(self) -> Dict[str, Any]:
        """The lossless structured form the exporter writes (and
        ``from_args`` reads back bit-for-bit)."""
        out: Dict[str, Any] = {
            "op": self.op, "stage": self.stage, "mb": self.mb,
            "chunk": self.chunk, "sl": self.sl, "phase": self.phase,
            "track": self.track,
        }
        if self.channel is not None:
            out["channel"] = list(self.channel)
        if self.hbm is not None:
            out["hbm"] = self.hbm
        return out


def make(op: str, stage: int, mb: int, chunk: int = 0, sl: int = 0,
         phase: str = "", start: float = 0.0, end: float = 0.0,
         track: str = COMPUTE, channel: Optional[Sequence] = None,
         hbm: Optional[float] = None) -> Span:
    """The span factory every constructor path routes through (keeps
    ``Span(`` construction inside this module — the check.sh seam)."""
    return Span(op=op, stage=int(stage), mb=int(mb), chunk=int(chunk),
                sl=int(sl), phase=phase, start=float(start),
                end=float(end), track=track,
                channel=None if channel is None else tuple(channel),
                hbm=None if hbm is None else float(hbm))


def from_args(args: Mapping[str, Any], start: float, end: float) -> Span:
    """Rebuild a span from its exported structured args (the exporter's
    lossless round trip — ``obs.export.load_trace`` calls this)."""
    return make(args["op"], args["stage"], args["mb"],
                args.get("chunk", 0), args.get("sl", 0),
                args.get("phase", ""), start, end,
                args.get("track", COMPUTE), args.get("channel"),
                args.get("hbm"))


class Observer:
    """The observer contract the engines speak.

    Subclass and override what you need; the base class swallows
    everything (attach-and-ignore is valid). The engines only ever call
    these three callbacks plus ``emit``:

      dispatch(stage, ins)        engine-order: the ready-loop retired
                                  one ``PlannedInstr`` (simulator and
                                  executor alike — ``obs.compare`` diffs
                                  these orders)
      span(span)                  one timed ``Span``
      counter(name, stage, t, v)  a named counter sample
    """

    def dispatch(self, stage: int, ins: Any) -> None:  # noqa: ARG002
        pass

    def span(self, span: Span) -> None:  # noqa: ARG002
        pass

    def counter(self, name: str, stage: int, t: float,
                value: float) -> None:  # noqa: ARG002
        pass

    # -- emission helper (the only Span construction call site) --------
    def emit(self, op: str, stage: int, mb: int, chunk: int = 0,
             sl: int = 0, phase: str = "", start: float = 0.0,
             end: float = 0.0, track: str = COMPUTE,
             channel: Optional[Sequence] = None,
             hbm: Optional[float] = None) -> None:
        self.span(make(op, stage, mb, chunk, sl, phase, start, end,
                       track, channel, hbm))


@dataclasses.dataclass
class DispatchRecord:
    """One engine-order event: which instruction the loop retired."""
    stage: int
    key: SpanKey


class Recorder(Observer):
    """Collects the full event stream of one run."""

    def __init__(self) -> None:
        self.spans: List[Span] = []
        self.dispatches: List[DispatchRecord] = []
        self.counters: Dict[Tuple[str, int], List[Tuple[float, float]]] = {}

    # -- observer callbacks --------------------------------------------
    def dispatch(self, stage: int, ins: Any) -> None:
        self.dispatches.append(DispatchRecord(
            stage, (ins.op, stage, getattr(ins, "mb", -1),
                    getattr(ins, "chunk", 0), getattr(ins, "sl", 0),
                    getattr(ins, "phase", ""))))

    def span(self, span: Span) -> None:
        self.spans.append(span)

    def counter(self, name: str, stage: int, t: float,
                value: float) -> None:
        self.counters.setdefault((name, stage), []).append((t, value))

    # -- derived views --------------------------------------------------
    @property
    def makespan(self) -> float:
        return max((s.end for s in self.spans), default=0.0)

    def compute_spans(self) -> List[Span]:
        return [s for s in self.spans if s.track == COMPUTE]

    def channel_spans(self) -> List[Span]:
        return [s for s in self.spans if s.track == CHANNEL]

    def keys(self) -> set:
        """The instruction set this run executed (compute track) — the
        differential invariant: simulator and executor streams of the
        same spec must produce the SAME set."""
        return {s.key for s in self.spans if s.track == COMPUTE}

    def stage_order(self, stage: int) -> List[SpanKey]:
        """Keys of the stage's compute spans in start order (ties broken
        by emission order) — what ordering-divergence audits compare."""
        idx = [(s.start, j, s.key)
               for j, s in enumerate(self.spans)
               if s.track == COMPUTE and s.stage == stage]
        return [k for _, _, k in sorted(idx)]
