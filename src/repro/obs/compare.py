"""Sim-vs-real divergence audits: align the simulated timeline of a
``ScheduleSpec`` against the executor's trace of the SAME spec and
report where they disagree.

The paper's §4 method stands on the claim that the discrete-event model
predicts the real pipeline; this module makes that claim checkable per
run instead of per paper table. Both engines emit the same canonical
span schema (``obs.events``), so alignment is exact — spans match by
``Span.key`` — and divergence decomposes into:

  * **census**: instructions one stream has and the other lacks
    (``missing_in_real`` / ``missing_in_sim``; the differential-fuzz
    invariant pins these to empty for every valid spec),
  * **time skew**: per-op total-duration ratio, normalized by the
    overall makespan ratio (``time_scale``) so the units cancel — a
    skew of 1.0 means the op consumes the same *share* of its step in
    both engines; skew > 1 means the real op is relatively slower than
    the simulator prices it,
  * **ordering divergence**: per-stage normalized inversion distance
    (Kendall tau) between the two engines' canonical start orders — 0.0
    when the real dispatch replays the simulated order exactly, 1.0
    when it is fully reversed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.obs.timeline import Timeline


@dataclasses.dataclass
class OpSkew:
    """Relative duration of one op class, real vs simulated."""
    op: str
    sim_total: float      # summed canonical sim durations (sim units)
    real_total: float     # summed canonical real durations (seconds)
    count: int            # canonical instructions of this op (both sides)
    skew: float           # (real share of real step) / (sim share of sim
    #                       step); 1.0 = the model prices the op's share
    #                       exactly


def _inversions(seq: List[int]) -> int:
    """Inversion count via merge sort (n log n — traces get long)."""
    if len(seq) < 2:
        return 0
    mid = len(seq) // 2
    left, right = seq[:mid], seq[mid:]
    inv = _inversions(left) + _inversions(right)
    merged, i, j = [], 0, 0
    while i < len(left) and j < len(right):
        if left[i] <= right[j]:
            merged.append(left[i])
            i += 1
        else:
            inv += len(left) - i
            merged.append(right[j])
            j += 1
    seq[:] = merged + left[i:] + right[j:]
    return inv


def order_divergence(sim_order: List, real_order: List) -> float:
    """Normalized Kendall distance between two key sequences over their
    common keys: 0.0 = same order, 1.0 = reversed."""
    pos = {k: idx for idx, k in enumerate(sim_order)}
    ranks = [pos[k] for k in real_order if k in pos]
    n = len(ranks)
    if n < 2:
        return 0.0
    return _inversions(ranks) / (n * (n - 1) / 2)


@dataclasses.dataclass
class CompareReport:
    """The alignment of one spec's simulated and real event streams."""
    label: str
    sim_count: int                      # canonical sim instructions
    real_count: int                     # canonical real instructions
    missing_in_real: List[Tuple]        # sim keys the real run never ran
    missing_in_sim: List[Tuple]         # real keys the model never priced
    time_scale: float                   # real makespan / sim makespan
    op_skew: List[OpSkew]
    order_div: Dict[int, float]         # stage -> normalized inversions

    @property
    def instruction_sets_match(self) -> bool:
        return not self.missing_in_real and not self.missing_in_sim

    @property
    def max_order_divergence(self) -> float:
        return max(self.order_div.values(), default=0.0)

    def format(self) -> str:
        lines = [f"# sim-vs-real audit: {self.label}",
                 f"instructions: sim={self.sim_count} real={self.real_count}"
                 f" missing_in_real={len(self.missing_in_real)}"
                 f" missing_in_sim={len(self.missing_in_sim)}",
                 f"time_scale (real/sim makespan): {self.time_scale:.4g}"]
        lines.append("op     n      sim_total  real_total  skew")
        for s in self.op_skew:
            lines.append(f"{s.op:<6} {s.count:<6d} {s.sim_total:<10.4g} "
                         f"{s.real_total:<11.4g} {s.skew:.3f}")
        div = " ".join(f"{i}:{d:.3f}" for i, d in sorted(
            self.order_div.items()))
        lines.append(f"order divergence per stage: {div}")
        return "\n".join(lines)

    def to_dict(self) -> Dict:
        return {
            "label": self.label, "sim_count": self.sim_count,
            "real_count": self.real_count,
            "missing_in_real": [list(k) for k in self.missing_in_real],
            "missing_in_sim": [list(k) for k in self.missing_in_sim],
            "time_scale": self.time_scale,
            "op_skew": [dataclasses.asdict(s) for s in self.op_skew],
            "order_divergence": {str(i): d
                                 for i, d in sorted(self.order_div.items())},
        }


def compare(sim_spans, real_spans, label: str = "") -> CompareReport:
    """Align two span streams of the same spec (any iterables of
    ``Span`` — live recorders, reloaded traces, timelines)."""
    sim = sim_spans if isinstance(sim_spans, Timeline) else Timeline(sim_spans)
    real = (real_spans if isinstance(real_spans, Timeline)
            else Timeline(real_spans))
    sim_keys, real_keys = sim.keys(), real.keys()
    scale = (real.makespan / sim.makespan
             if sim.makespan > 0 and real.makespan > 0 else 0.0)
    totals: Dict[str, List[float]] = {}
    for tl, slot in ((sim, 0), (real, 1)):
        for s in tl.canonical():
            totals.setdefault(s.op, [0.0, 0.0, 0])[slot] += s.duration
    counts, real_counts = sim.ops(), real.ops()
    skews = []
    for op in sorted(totals):
        st, rt, _ = totals[op]
        sim_share = st / sim.makespan if sim.makespan > 0 else 0.0
        real_share = rt / real.makespan if real.makespan > 0 else 0.0
        skews.append(OpSkew(
            op=op, sim_total=st, real_total=rt,
            count=counts.get(op, real_counts.get(op, 0)),
            skew=real_share / sim_share if sim_share > 0 else 0.0))
    div = {i: order_divergence(sim.order(i), real.order(i))
           for i in range(max(sim.p, real.p))}
    return CompareReport(
        label=label, sim_count=len(sim.canonical()),
        real_count=len(real.canonical()),
        missing_in_real=sorted(sim_keys - real_keys),
        missing_in_sim=sorted(real_keys - sim_keys),
        time_scale=scale, op_skew=skews, order_div=div)


def audit(cfg, spec, micro_batch: int = 1, seq: int = 32,
          t_p2p: float = 0.0, seed: int = 0) -> CompareReport:
    """End-to-end audit of one spec on one model config: run the real
    executor traced, fit simulator costs from its trace, simulate the
    same spec under those costs, and compare the two streams. Heavy
    imports stay inside — the compare layer itself has no jax edge."""
    import jax

    from repro.core import simulator as SIM
    from repro.models import model as M
    from repro.obs.events import Recorder
    from repro.pipeline.executor import PipelineExecutor
    from repro.planner import calibrate

    assert spec.bound, f"audit needs a bound spec (m > 0): {spec}"
    ex = PipelineExecutor(cfg, spec=spec, micro_batch=micro_batch)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1),
                              (spec.m * micro_batch, seq + 1),
                              0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ex.step(params, batch)                       # warm / compile
    res = ex.step(params, batch, trace=True)
    costs = calibrate.fit_trace(res.events, v=spec.v, b=micro_batch,
                                seq_chunks=spec.seq_chunks)
    rec = Recorder()
    SIM.simulate(SIM.SimConfig(spec=spec, Tf=costs.Tf, Tb=costs.Tb,
                               t_p2p=t_p2p,
                               evict_bytes=(costs.t_move or 0.0),
                               pair_bw=1.0 if costs.t_move else float("inf")),
                 observer=rec)
    return compare(rec.spans, res.events, label=spec.label())
