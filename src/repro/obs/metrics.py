"""Metrics registry: the derived quantities one span stream supports.

Everything here is a pure fold over the canonical event schema
(``obs.events``) — no engine callbacks, no second bookkeeping path. The
same functions summarize a simulated run (sim-time units) and a real
executor step (wall-clock seconds):

  * per-stage busy time, bubble fraction, WAIT-stall time and
    warmup/steady/drain phase splits (warmup ends at the stage's first
    backward; drain starts after its last forward — the 1F1B phase
    anatomy the paper's eq. 2/3 reason about),
  * per-channel occupancy: moves, busy (link-occupied) time, stall
    (data-ready-but-link-busy) time, utilization, and the in-flight
    peak recovered by sweeping the channel's span overlaps,
  * MFU from the makespan (``simulator.mfu_from_sim``'s formula, over
    observed spans),
  * a stepwise HBM-residency timeline: executor spans carry real store
    byte samples (``Span.hbm``); simulator spans are re-priced through
    the same byte weights ``memory_model``/``memory.store`` charge, so
    both engines produce comparable memory counter tracks for the
    Perfetto exporter.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union

from repro.core.schedule import B, F
from repro.memory import policy as respol
from repro.obs import events as E
from repro.obs.timeline import Timeline


@dataclasses.dataclass
class StageMetrics:
    """Per-stage anatomy of one step."""
    stage: int
    busy: float             # summed F/B (+RECOMPUTE re-forward) time
    stall: float            # summed WAIT-half time (completion barriers)
    warmup: float           # step start -> first B start
    steady: float           # first B start -> last F end
    drain: float            # last F end -> stage's last event end
    hbm_peak: float = 0.0   # peak resident bytes (0 if no byte source)

    @property
    def bubble_fraction(self) -> float:
        total = self.warmup + self.steady + self.drain
        return 1.0 - self.busy / total if total > 0 else 0.0


@dataclasses.dataclass
class ChannelMetrics:
    """Per-channel occupancy over one step."""
    key: Tuple
    moves: int
    busy: float             # summed transfer (link-occupancy) time
    stall: float            # summed data-ready-but-link-busy wait
    queue_peak: int         # max concurrently in-flight transfers

    def utilization(self, makespan: float) -> float:
        return self.busy / makespan if makespan > 0 else 0.0


@dataclasses.dataclass
class StepMetrics:
    """Everything the registry derives from one run's span stream."""
    makespan: float
    stages: List[StageMetrics]
    channels: List[ChannelMetrics]
    mfu: Optional[float] = None

    @property
    def bubble_fraction(self) -> float:
        total = self.makespan * len(self.stages)
        if total <= 0:
            return 0.0
        return 1.0 - sum(s.busy for s in self.stages) / total

    @property
    def stall(self) -> float:
        return sum(s.stall for s in self.stages)

    @property
    def hbm_peak(self) -> float:
        return max((s.hbm_peak for s in self.stages), default=0.0)

    @property
    def channel_busy(self) -> float:
        return sum(c.busy for c in self.channels)

    def channel_occupancy(self) -> float:
        """Max per-channel utilization — how close the busiest link is
        to being the bottleneck."""
        return max((c.utilization(self.makespan) for c in self.channels),
                   default=0.0)

    def to_dict(self) -> Dict:
        return {
            "makespan": self.makespan,
            "bubble_fraction": self.bubble_fraction,
            "stall": self.stall,
            "mfu": self.mfu,
            "hbm_peak": self.hbm_peak,
            "stages": [dataclasses.asdict(s) | {
                "bubble_fraction": s.bubble_fraction}
                for s in self.stages],
            "channels": [{
                "key": list(c.key), "moves": c.moves, "busy": c.busy,
                "stall": c.stall, "queue_peak": c.queue_peak,
                "utilization": c.utilization(self.makespan)}
                for c in self.channels],
        }


#: Ops whose span time is stage *compute* (busy): F, B, and every
#: recompute-mechanism restore (the re-forward bill).
def _busy_ops() -> frozenset:
    extra = {op for op, pol in respol.RESTORE_OPS.items()
             if pol.mechanism == "recompute"}
    return frozenset({F, B} | extra)


def _queue_peak(spans: List[E.Span]) -> int:
    """Max overlap among a channel's spans (sweep over endpoints)."""
    edges = []
    for s in spans:
        edges.append((s.start, 1))
        edges.append((s.end, -1))
    edges.sort(key=lambda e: (e[0], e[1]))
    cur = peak = 0
    for _, d in edges:
        cur += d
        peak = max(peak, cur)
    return peak


def compute(spans, p: Optional[int] = None,
            model_flops: Optional[float] = None, t: int = 1,
            peak_flops: Optional[float] = None,
            channel_stats: Optional[Mapping] = None) -> StepMetrics:
    """Fold a span stream into ``StepMetrics``.

    ``p`` widens the stage list beyond the stages that emitted spans
    (an idle stage is still a stage). ``model_flops``/``peak_flops``
    enable the MFU line. ``channel_stats`` (a ``SimResult.channels``
    mapping) refines channel stall/queue-peak with the engine's own
    accounting when available; otherwise both are recovered from the
    channel spans."""
    tl = spans if isinstance(spans, Timeline) else Timeline(spans)
    makespan = tl.makespan
    busy_ops = _busy_ops()
    n_stages = max(p or 0, tl.p)
    stages = []
    for i in range(n_stages):
        group = tl.stage(i)
        busy = sum(s.duration for s in group
                   if s.canonical and s.op in busy_ops)
        stall = sum(s.duration for s in group if s.is_wait)
        b_starts = [s.start for s in group if s.op == B and s.canonical]
        f_ends = [s.end for s in group if s.op == F and s.canonical]
        last = max((s.end for s in group), default=0.0)
        warmup = min(b_starts) if b_starts else last
        drain_from = max(f_ends) if f_ends else last
        hbm = max((s.hbm for s in group if s.hbm is not None),
                  default=0.0)
        stages.append(StageMetrics(
            stage=i, busy=busy, stall=stall, warmup=warmup,
            steady=max(0.0, drain_from - warmup),
            drain=max(0.0, last - drain_from), hbm_peak=hbm))
    channels = []
    for key in sorted(tl.by_channel):
        group = tl.channel(key)
        st = channel_stats.get(key) if channel_stats else None
        channels.append(ChannelMetrics(
            key=key, moves=len(group),
            busy=sum(s.duration for s in group),
            stall=getattr(st, "stall", 0.0),
            queue_peak=(getattr(st, "queue_peak", 0) if st
                        else _queue_peak(group))))
    mfu = None
    if model_flops and peak_flops and makespan > 0 and n_stages:
        mfu = model_flops / (makespan * n_stages * t * peak_flops)
    return StepMetrics(makespan=makespan, stages=stages,
                       channels=channels, mfu=mfu)


# ---------------------------------------------------------------------------
# HBM residency timeline
# ---------------------------------------------------------------------------
#: Per-stage byte weight of one stash unit: a flat float, or
#: ``(stage, chunk) -> bytes`` — the same contract
#: ``memory.store.ActivationStore`` weighs with.
UnitBytes = Union[float, Callable[[int, int], float]]


def hbm_timeline(spans, partner: Mapping[int, int],
                 unit_bytes: UnitBytes, retained_bytes: float = 0.0,
                 p: Optional[int] = None,
                 ) -> Dict[int, List[Tuple[float, float]]]:
    """Stepwise per-stage resident-byte series from a span stream.

    Executor spans carry measured store samples (``Span.hbm``) — those
    are used verbatim. Simulator spans carry no bytes, so the series is
    re-priced from the op semantics with the SAME byte weights the
    store and ``memory_model`` charge: F stashes one unit, B frees it,
    a swap release ships it to ``partner``, a host release moves it off
    the device, a recompute release keeps ``retained_bytes``; restores
    reverse their release. Returns ``{stage: [(t, bytes), ...]}`` in
    time order, one sample per byte-changing event."""
    tl = spans if isinstance(spans, Timeline) else Timeline(spans)
    w_fn = unit_bytes if callable(unit_bytes) \
        else (lambda stage, chunk, w=float(unit_bytes): w)
    n_stages = max(p or 0, tl.p)
    cur = {i: 0.0 for i in range(n_stages)}
    out: Dict[int, List[Tuple[float, float]]] = {
        i: [(0.0, 0.0)] for i in range(n_stages)}
    measured = any(s.hbm is not None for s in tl.spans)
    ordered = sorted((s for s in tl.spans if s.track == E.COMPUTE),
                     key=lambda s: (s.end, s.start))
    for s in ordered:
        i = s.stage
        if measured:
            if s.hbm is not None:
                out[i].append((s.end, s.hbm))
            continue
        if not s.canonical:
            continue
        w = w_fn(i, s.chunk)
        if s.op == F:
            cur[i] += w
        elif s.op == B:
            cur[i] -= w
        elif s.op in respol.RELEASE_OPS:
            pol = respol.RELEASE_OPS[s.op]
            cur[i] -= w
            if pol.swap:
                j = partner[i]
                cur[j] += w_fn(i, s.chunk)
                out[j].append((s.end, cur[j]))
            elif pol.mechanism == "recompute":
                cur[i] += retained_bytes
        elif s.op in respol.RESTORE_OPS:
            pol = respol.RESTORE_OPS[s.op]
            cur[i] += w
            if pol.swap:
                j = partner[i]
                cur[j] -= w_fn(i, s.chunk)
                out[j].append((s.end, cur[j]))
            elif pol.mechanism == "recompute":
                cur[i] -= retained_bytes
        else:
            continue
        out[i].append((s.end, cur[i]))
    return out


def hbm_peaks(timeline: Mapping[int, List[Tuple[float, float]]],
              ) -> Dict[int, float]:
    return {i: max((v for _, v in series), default=0.0)
            for i, series in timeline.items()}
