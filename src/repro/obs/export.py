"""The unified Perfetto / Chrome-trace exporter — one serialization of
the canonical event schema, replacing the two ad-hoc emitters that used
to live in ``pipeline.executor`` (TraceEvent capture) and
``planner.calibrate`` (``chrome_trace``).

Layout (open in https://ui.perfetto.dev or chrome://tracing):

  * pid 0 "stages"   — one thread row per pipeline stage; every
    compute-track span is a complete ("X") event named by its
    presentation label (``EVICT3.c1.s2+w``).
  * pid 1 "channels" — one thread row per transfer channel (pair links,
    D2H/H2D host links); channel-occupancy spans land here.
  * pid 0 counters   — ``hbm@<stage>`` counter ("C") tracks: the
    stepwise resident-byte series from ``obs.metrics.hbm_timeline``
    (or the executor's measured store samples riding on the spans).

The round trip is lossless: every span's structured identity
(op/stage/mb/chunk/sl/phase/track/channel/hbm) is written into the
event's ``args`` and ``load_trace`` rebuilds the exact ``Span`` — no
more re-parsing (and dropping) ``.sN``/``+w`` suffixes from name
strings. Legacy traces saved by the old ``calibrate.chrome_trace``
(no structured args) still load: the op string is split back into
(op, sl, phase) by suffix.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from repro.obs import events as E

#: Synthetic process ids grouping the track rows.
PID_STAGES, PID_CHANNELS = 0, 1

_LEGACY_WAIT = re.compile(r"\+w$")
_LEGACY_SLICE = re.compile(r"\.s(\d+)")


def _channel_tid(key: Tuple, index: Dict[Tuple, int]) -> int:
    if key not in index:
        index[key] = len(index)
    return index[key]


def to_chrome(spans: Iterable[E.Span],
              counters: Optional[Mapping[int, List[Tuple[float, float]]]]
              = None,
              time_scale: float = 1e6) -> dict:
    """Serialize spans (+ optional per-stage byte counters) to the
    Chrome trace-event format Perfetto reads. ``time_scale`` converts
    span times to microseconds (1e6 for wall-clock seconds; simulated
    unit-time traces view fine at the same scale)."""
    out: List[dict] = []
    chans: Dict[Tuple, int] = {}
    meta = [
        {"name": "process_name", "ph": "M", "pid": PID_STAGES,
         "args": {"name": "stages"}},
        {"name": "process_name", "ph": "M", "pid": PID_CHANNELS,
         "args": {"name": "channels"}},
    ]
    for s in spans:
        if s.track == E.CHANNEL:
            pid, tid = PID_CHANNELS, _channel_tid(s.channel, chans)
        else:
            pid, tid = PID_STAGES, s.stage
        out.append({
            "name": s.label, "cat": s.op, "ph": "X",
            "ts": s.start * time_scale,
            "dur": s.duration * time_scale,
            "pid": pid, "tid": tid,
            "args": s.to_args(),
        })
    for key, tid in chans.items():
        meta.append({"name": "thread_name", "ph": "M",
                     "pid": PID_CHANNELS, "tid": tid,
                     "args": {"name": ":".join(map(str, key))}})
    if counters:
        for stage in sorted(counters):
            for t, v in counters[stage]:
                out.append({
                    "name": f"hbm@{stage}", "ph": "C",
                    "ts": t * time_scale, "pid": PID_STAGES,
                    "tid": stage, "args": {"bytes": v},
                })
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def save_trace(spans: Iterable[E.Span], path: str,
               counters: Optional[Mapping[int, List[Tuple[float, float]]]]
               = None) -> None:
    with open(path, "w") as f:
        json.dump(to_chrome(spans, counters), f)


def _legacy_span(rec: dict, start: float, end: float) -> E.Span:
    """Rebuild a span from a pre-obs trace record (the old
    ``calibrate.chrome_trace`` format): structured fields live only in
    the op string, so split the ``.sN`` / ``+w`` suffixes back out —
    exactly the distinctions the old loader dropped."""
    op = rec.get("cat") or rec.get("name", "")
    phase = ""
    if _LEGACY_WAIT.search(op):
        op = _LEGACY_WAIT.sub("", op)
        phase = E.WAIT
    sl = 0
    m = _LEGACY_SLICE.search(op)
    if m:
        sl = int(m.group(1))
        op = _LEGACY_SLICE.sub("", op)
    args = rec.get("args", {})
    return E.make(op, rec.get("tid", 0), args.get("mb", 0),
                  args.get("chunk", 0), sl, phase, start, end)


def load_trace(path: str) -> List[E.Span]:
    """Parse a saved trace back into ``Span``s — bit-exact for traces
    this exporter wrote (structured args), best-effort suffix parsing
    for legacy ``chrome_trace`` files."""
    with open(path) as f:
        doc = json.load(f)
    spans: List[E.Span] = []
    for rec in doc.get("traceEvents", []):
        if rec.get("ph") != "X":
            continue
        start = rec["ts"] / 1e6
        end = start + rec.get("dur", 0.0) / 1e6
        args = rec.get("args", {})
        if "op" in args:
            spans.append(E.from_args(args, start, end))
        else:
            spans.append(_legacy_span(rec, start, end))
    return spans
