"""Timeline: the ordered, per-track view over one run's span stream.

A ``Recorder`` collects spans in emission order; consumers (metrics,
export, compare) want them *organized* — per stage in time order, per
channel, with the run's extent resolved. ``Timeline`` is that view,
built once from any span iterable (a live ``Recorder``, a reloaded
Perfetto trace, a filtered subset) without copying payloads.

Simulated and real runs produce the same structure, which is the whole
point: ``obs.compare`` aligns two ``Timeline``s without caring which
engine produced which.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs import events as E


class Timeline:
    """Spans of one run, indexed by track / stage / channel."""

    def __init__(self, spans: Iterable[E.Span]):
        self.spans: List[E.Span] = list(spans)
        self.by_stage: Dict[int, List[E.Span]] = {}
        self.by_channel: Dict[Tuple, List[E.Span]] = {}
        for j, s in enumerate(self.spans):
            if s.track == E.CHANNEL:
                self.by_channel.setdefault(s.channel, []).append(s)
            else:
                self.by_stage.setdefault(s.stage, []).append(s)
        for group in self.by_stage.values():
            group.sort(key=lambda s: (s.start, s.end))
        for group in self.by_channel.values():
            group.sort(key=lambda s: (s.start, s.end))

    # -- extent ----------------------------------------------------------
    @property
    def p(self) -> int:
        """Stage count (highest stage seen + 1)."""
        return max(self.by_stage, default=-1) + 1

    @property
    def makespan(self) -> float:
        return max((s.end for s in self.spans), default=0.0)

    @property
    def start(self) -> float:
        return min((s.start for s in self.spans), default=0.0)

    # -- selections ------------------------------------------------------
    def stage(self, i: int) -> List[E.Span]:
        return self.by_stage.get(i, [])

    def channel(self, key: Tuple) -> List[E.Span]:
        return self.by_channel.get(key, [])

    def canonical(self, stage: Optional[int] = None) -> List[E.Span]:
        """Canonical compute-track spans (WAIT barriers excluded) — one
        per instruction, what counts and medians bin over."""
        src = self.spans if stage is None else self.stage(stage)
        return [s for s in src if s.canonical]

    def ops(self) -> Dict[str, int]:
        """Canonical instruction census by op."""
        out: Dict[str, int] = {}
        for s in self.canonical():
            out[s.op] = out.get(s.op, 0) + 1
        return out

    def keys(self) -> set:
        """Compute-track span identities (WAIT halves included — they
        are instructions too; the differential invariant compares full
        sets)."""
        return {s.key for group in self.by_stage.values() for s in group}

    def order(self, stage: int) -> List[E.SpanKey]:
        """The stage's canonical keys in start order — the sequence
        ordering-divergence audits compare across engines."""
        return [s.key for s in self.stage(stage) if s.canonical]

    # -- derived scalars -------------------------------------------------
    def busy(self, stage: int, ops: Optional[Tuple[str, ...]] = None,
             ) -> float:
        """Summed canonical span time on a stage (optionally only the
        given ops)."""
        return sum(s.duration for s in self.canonical(stage)
                   if ops is None or s.op in ops)
