"""train_step / prefill_step / serve_step factories with sharding.

These are the functions the dry-run lowers on the production mesh for
every (architecture x input shape): training shapes lower ``train_step``,
prefill shapes lower ``prefill_step``, decode shapes lower ``serve_step``
(ONE new token against a seq_len KV cache), per the assignment.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model as M
from repro.optim import adam
from repro.sharding import rules


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                    donate: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg, remat=tcfg.remat),
            has_aux=True)(params)
        params, opt_state, opt_metrics = adam.update(
            params, grads, opt_state, tcfg)
        metrics = dict(metrics, **opt_metrics, total=loss)
        return params, opt_state, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    def shardings(params, opt_state, batch):
        ps = rules.param_shardings(params, mesh)
        os_ = adam.AdamState(
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            m=ps, v=ps)
        bs = rules.batch_shardings(batch, mesh)
        return ps, os_, bs

    return step, shardings


def make_loss_grad(cfg: ModelConfig, tcfg: TrainConfig):
    """Bare loss+grad (no optimizer) — used by some benchmarks."""

    def f(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, batch, cfg, remat=tcfg.remat),
            has_aux=True)(params)
        return loss, grads

    return f


def make_prefill_step(cfg: ModelConfig):
    """(params, batch, state) -> (logits_last, state)."""

    def step(params, batch, state):
        logits, state, _ = M.prefill(params, batch, cfg, state)
        return logits, state

    return step


def make_serve_step(cfg: ModelConfig, sample: str = "greedy"):
    """One decode step: (params, state, token, pos[, enc_states])
    -> (next_token, logits, state)."""

    def step(params, state, token, pos, enc_states=None):
        logits, state = M.decode_step(params, token, pos, state, cfg,
                                      enc_states=enc_states)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, state

    return step


def init_all(cfg: ModelConfig, seed: int = 0):
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adam.init(params)
    return params, opt_state
