"""The activation store: per-device stash of vjp closures with
residency-aware slots and byte accounting (re-homed from
``pipeline.executor.ActivationStore``).

Four slot classes per device:
  local[i]    the device's own live residuals, keyed (mb, chunk, sl)
  foreign[i]  units accepted from the paired BPipe evictor,
              keyed (owner_stage, mb, chunk, sl)
  host[i]     units offloaded to host memory (device bytes: zero)
  dropped[i]  units whose residuals were freed; only the retained
              boundary input remains (device bytes: ``retained_bytes``)

``sl`` is the sequence slice (``ScheduleSpec.seq_chunks`` > 1 — 0 for
unsliced schedules): a sliced unit is a first-class stash like any
other, so every residency policy manages sliced KV with zero new
mechanism. ``peek`` reads a unit's payload WHEREVER it lives — a later
slice's forward must reach the retained-KV prefix even after a policy
released the unit (docs/longcontext.md).

Byte accounting uses a per-(owner_stage, chunk) weight — the same
v-chunk weighting ``core.memory_model.act_bytes_per_stage`` charges
(each interleaved unit holds 1/v of the device's layers) — so
executor-reported ``peak_bytes``/``bytes_moved`` agree with the memory
model's per-stage numbers instead of a single flat per-unit float.
``peak_local`` counts device-resident *full* units (local + foreign),
which is what the compiled plan's cap/bounds are asserted against;
``peak_bytes`` additionally carries the dropped units' retained bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple, Union

Unit = Tuple[int, int, int]  # (mb, chunk, sl) — one stash unit

#: Per-unit byte weight: a flat float, or ``(owner_stage, chunk) -> bytes``
#: for schedules whose units differ in size. Sliced schedules use a
#: uniform per-slice weight (``memory_model.sliced_unit_bytes``), so the
#: callable signature needs no slice argument.
UnitBytes = Union[float, Callable[[int, int], float]]


@dataclasses.dataclass
class StoreStats:
    peak_local: Dict[int, int]
    peak_bytes: Dict[int, float]
    evictions: int
    loads: int
    bytes_moved: float
    offloads: int = 0
    fetches: int = 0
    drops: int = 0
    recomputes: int = 0
    host_peak_bytes: Dict[int, float] = dataclasses.field(default_factory=dict)
    transfers_inflight_peak: int = 0   # max in-flight moves on one channel
    #                                    (executor transfer runtime; at most
    #                                    ScheduleSpec.depth — the slot is
    #                                    reserved before the copy starts)


class ActivationStore:
    """Residency-aware per-device stash with live peak accounting."""

    def __init__(self, p: int, unit_bytes: UnitBytes = 0.0,
                 retained_bytes: float = 0.0):
        self.p = p
        self._w = unit_bytes if callable(unit_bytes) \
            else (lambda stage, chunk, w=float(unit_bytes): w)
        self.retained_bytes = retained_bytes
        self.local: List[Dict[Unit, Any]] = [dict() for _ in range(p)]
        self.foreign: List[Dict[Tuple[int, int, int, int], Any]] = [
            dict() for _ in range(p)]
        self.host: List[Dict[Unit, Any]] = [dict() for _ in range(p)]
        self.dropped: List[Dict[Unit, Any]] = [dict() for _ in range(p)]
        self.peak: Dict[int, int] = {i: 0 for i in range(p)}
        self.cur_bytes: Dict[int, float] = {i: 0.0 for i in range(p)}
        self.peak_bytes: Dict[int, float] = {i: 0.0 for i in range(p)}
        self.host_bytes: Dict[int, float] = {i: 0.0 for i in range(p)}
        self.host_peak_bytes: Dict[int, float] = {i: 0.0 for i in range(p)}
        self.evictions = 0
        self.loads = 0
        self.offloads = 0
        self.fetches = 0
        self.drops = 0
        self.recomputes = 0
        self.bytes_moved = 0.0

    # -- accounting helpers ------------------------------------------------
    def unit_bytes(self, owner: int, chunk: int) -> float:
        return self._w(owner, chunk)

    def _bump(self, i: int) -> None:
        n = len(self.local[i]) + len(self.foreign[i])
        self.peak[i] = max(self.peak[i], n)
        self.peak_bytes[i] = max(self.peak_bytes[i], self.cur_bytes[i])

    def _add_bytes(self, i: int, delta: float) -> None:
        self.cur_bytes[i] += delta

    def held(self, i: int) -> int:
        """Device-resident full units (what the stash cap bounds)."""
        return len(self.local[i]) + len(self.foreign[i])

    def resident_bytes(self, i: int) -> float:
        """Current device-resident activation bytes on stage ``i`` — the
        live sample the executor attaches to each span (``Span.hbm``) so
        observed traces carry a real memory counter track."""
        return self.cur_bytes[i]

    # -- live residency ----------------------------------------------------
    def put(self, i: int, mb: int, stash: Any, chunk: int = 0,
            sl: int = 0) -> None:
        assert (mb, chunk, sl) not in self.local[i], (i, mb, chunk, sl)
        self.local[i][(mb, chunk, sl)] = stash
        self._add_bytes(i, self._w(i, chunk))
        self._bump(i)

    def pop(self, i: int, mb: int, chunk: int = 0, sl: int = 0) -> Any:
        stash = self.local[i].pop((mb, chunk, sl))
        self._add_bytes(i, -self._w(i, chunk))
        return stash

    def peek(self, i: int, mb: int, chunk: int = 0, sl: int = 0) -> Any:
        """Read a unit's payload wherever it currently lives — local,
        shipped to a partner, host-offloaded, or residual-dropped —
        without moving or re-accounting it. The sliced forward's
        retained-KV reads go through this, so no residency policy can
        deadlock a later slice by releasing an earlier one (reading a
        host/partner-resident array costs a transfer the runtime
        already overlaps; the bytes stay charged where the unit lives).
        """
        key = (mb, chunk, sl)
        ent = self.local[i].get(key)
        if ent is not None:
            return ent
        for j in range(self.p):
            ent = self.foreign[j].get((i, mb, chunk, sl))
            if ent is not None:
                return ent
        ent = self.host[i].get(key)
        if ent is not None:
            return ent
        return self.dropped[i][key]

    # -- bpipe_swap: partner store ----------------------------------------
    def evict(self, i: int, mb: int, partner: int, chunk: int = 0,
              sl: int = 0) -> Any:
        """Ship (mb, chunk, sl) to the paired acceptor; returns the moved
        stash (the in-flight payload the transfer runtime tracks)."""
        stash = self.local[i].pop((mb, chunk, sl))
        self.foreign[partner][(i, mb, chunk, sl)] = stash
        w = self._w(i, chunk)
        self.evictions += 1
        self.bytes_moved += w
        self._add_bytes(i, -w)
        self._add_bytes(partner, w)
        self._bump(partner)
        return stash

    def load(self, i: int, mb: int, partner: int, chunk: int = 0,
             sl: int = 0) -> Any:
        stash = self.foreign[partner].pop((i, mb, chunk, sl))
        self.local[i][(mb, chunk, sl)] = stash
        w = self._w(i, chunk)
        self.loads += 1
        self.bytes_moved += w
        self._add_bytes(partner, -w)
        self._add_bytes(i, w)
        self._bump(i)
        return stash

    # -- host_offload: D2H / H2D ------------------------------------------
    def offload(self, i: int, mb: int, chunk: int = 0, sl: int = 0,
                mover: Callable[[Any], Any] = lambda s: s) -> Any:
        stash = mover(self.local[i].pop((mb, chunk, sl)))
        self.host[i][(mb, chunk, sl)] = stash
        w = self._w(i, chunk)
        self.offloads += 1
        self.bytes_moved += w
        self._add_bytes(i, -w)
        self.host_bytes[i] += w
        self.host_peak_bytes[i] = max(self.host_peak_bytes[i],
                                      self.host_bytes[i])
        return stash

    def fetch(self, i: int, mb: int, chunk: int = 0, sl: int = 0,
              mover: Callable[[Any], Any] = lambda s: s) -> Any:
        stash = mover(self.host[i].pop((mb, chunk, sl)))
        self.local[i][(mb, chunk, sl)] = stash
        w = self._w(i, chunk)
        self.fetches += 1
        self.bytes_moved += w
        self.host_bytes[i] -= w
        self._add_bytes(i, w)
        self._bump(i)
        return stash

    # -- selective_recompute: free residuals, keep the boundary input ------
    def drop(self, i: int, mb: int, chunk: int = 0, sl: int = 0,
             strip: Callable[[Any], Any] = lambda entry: None) -> None:
        """Free (mb, chunk, sl)'s residuals, keeping only ``strip(entry)``
        (the boundary input the re-forward starts from — plus the slice's
        own KV under sequence slicing)."""
        entry = self.local[i].pop((mb, chunk, sl))
        self.dropped[i][(mb, chunk, sl)] = strip(entry)
        self.drops += 1
        self._add_bytes(i, -(self._w(i, chunk) - self.retained_bytes))

    def dropped_input(self, i: int, mb: int, chunk: int = 0,
                      sl: int = 0) -> Any:
        return self.dropped[i][(mb, chunk, sl)]

    def recompute(self, i: int, mb: int, stash: Any, chunk: int = 0,
                  sl: int = 0) -> None:
        """Re-install the residuals ``stash`` rebuilt by the re-forward."""
        del self.dropped[i][(mb, chunk, sl)]
        self.local[i][(mb, chunk, sl)] = stash
        self.recomputes += 1
        self._add_bytes(i, self._w(i, chunk) - self.retained_bytes)
        self._bump(i)

    def stats(self) -> StoreStats:
        return StoreStats(
            peak_local=dict(self.peak),
            peak_bytes=dict(self.peak_bytes),
            evictions=self.evictions, loads=self.loads,
            bytes_moved=self.bytes_moved,
            offloads=self.offloads, fetches=self.fetches,
            drops=self.drops, recomputes=self.recomputes,
            host_peak_bytes=dict(self.host_peak_bytes))
