"""``selective_recompute`` residency: free the vjp residuals, re-forward.

The paper's recompute arms treat recomputation as an *attention* knob
baked into the cost model; this policy makes it a schedulable residency
mechanism instead: DROP frees a held unit's vjp residuals (keeping only
the boundary input activation it arrived with — ``retained_bytes`` =
2sbh/t), and RECOMPUTE re-runs that (virtual) stage's forward from the
retained input just before the backward, rebuilding the residuals the
backward consumes. No bytes move (``moves_data`` is False); the cost is
FLOPs — the simulator charges one chunk-level forward (Tf/v) per
RECOMPUTE on the stage's compute frontier, and the executor really
re-runs ``jax.vjp`` so loss/grads stay bit-identical to the un-dropped
execution (the forward is deterministic).

Selection is the same cap-driven spill as BPipe's balancing: the unit
whose backward is farthest away is dropped first, bounded by the same
default cap — so bpipe_swap / host_offload / selective_recompute differ
*only* in mechanism, which is what makes the planner's three-way contest
(paper Table 3) a fair one.
"""
from __future__ import annotations

from repro.core.notation import Notation
from repro.core.schedule import DROP, RECOMPUTE
from repro.memory import policy as respol


def boundary_bytes(n: Notation, attention: str, v: int) -> float:
    """Device bytes a dropped unit retains: the stage's boundary input
    activation (2sbh/t — the tensor the re-forward starts from)."""
    return 2.0 * n.s * n.b * n.h / n.t


SELECTIVE_RECOMPUTE = respol.register(respol.ResidencyPolicy(
    "selective_recompute", DROP, RECOMPUTE, mechanism="recompute",
    default_cap=respol.residency_cap,
    cap_roof=respol.residency_cap_roof,
    retained_bytes=boundary_bytes))
