"""``host_offload`` residency: spill stashed activations to host DRAM.

The SlimPipe-style alternative to BPipe's partner swap: instead of
shipping the newest held unit to the paired *device*, OFFLOAD copies it
to host memory over the D2H link and FETCH copies it back ahead of the
backward. Same spill discipline (``policy.spill``), same cap formulas —
what changes is the link: host bandwidth (PCIe-class) instead of
NVLink/ICI, which is exactly the trade the simulator prices
(``SimConfig.d2h_bw/h2d_bw``) and the planner searches.

In the executor the copy is real: ``jax.vjp``'s returned function is a
``tree_util.Partial`` pytree whose leaves are the residual arrays, so
``jax.device_put`` moves the whole stash to the host platform and back
bit-identically (``to_host`` / ``to_device``).
"""
from __future__ import annotations

from typing import Any

from repro.core.schedule import FETCH, OFFLOAD
from repro.memory import policy as respol


def to_host(stash: Any) -> Any:
    """Move a stash (any pytree — including a vjp closure) to host
    memory. Real ``jax.device_put`` onto the CPU platform; on a
    CPU-only runtime this degenerates to a no-op copy, which keeps the
    numerics contract (bit-identical round trip) testable anywhere."""
    import jax
    return jax.device_put(stash, jax.devices("cpu")[0])


def to_device(stash: Any) -> Any:
    """Move an offloaded stash back to the default accelerator."""
    import jax
    return jax.device_put(stash, jax.devices()[0])


HOST_OFFLOAD = respol.register(respol.ResidencyPolicy(
    "host_offload", OFFLOAD, FETCH, mechanism="host",
    default_cap=respol.residency_cap,
    cap_roof=respol.residency_cap_roof))
