"""``repro.memory``: the activation-residency subsystem.

Where a stashed activation lives between its F and its B is an axis
orthogonal to the pipeline-schedule kind. This package owns it:

  * ``policy``    — the ``ResidencyPolicy`` contract, the shared
                    cap-driven ``spill`` rewrite, and the registry that
                    extends the schedule op set (``none``/``bpipe_swap``
                    built in).
  * ``offload``   — ``host_offload``: OFFLOAD/FETCH to host DRAM
                    (real ``jax.device_put`` in the executor, D2H/H2D
                    bandwidth in the simulator).
  * ``recompute`` — ``selective_recompute``: DROP the vjp residuals,
                    RECOMPUTE the forward ahead of the backward
                    (FLOPs-costed; bit-identical numerics).
  * ``store``     — the residency-aware ``ActivationStore`` the executor
                    interprets stashes with (per-chunk byte weighting).

See docs/memory.md for the policy contract and how to register one.
"""
from repro.memory import offload, policy, recompute, store
from repro.memory.offload import HOST_OFFLOAD
from repro.memory.policy import (BPIPE_SWAP, NONE, POLICIES, RELEASE_OPS,
                                 RESTORE_OPS, ResidencyPolicy, register,
                                 residency_cap, residency_cap_roof, spill,
                                 unregister)
from repro.memory.recompute import SELECTIVE_RECOMPUTE
from repro.memory.store import ActivationStore, StoreStats

__all__ = [
    "ActivationStore", "BPIPE_SWAP", "HOST_OFFLOAD", "NONE", "POLICIES",
    "RELEASE_OPS", "RESTORE_OPS", "ResidencyPolicy", "SELECTIVE_RECOMPUTE",
    "StoreStats", "offload", "policy", "recompute", "register",
    "residency_cap", "residency_cap_roof", "spill", "store", "unregister",
]
