"""Activation-residency policies: where a stashed activation lives
between its F and its B.

The paper's central comparison (§4, Table 3) is a three-way contest
between residency strategies — BPipe's partner swap vs. recomputation
vs. footprint reduction — and related systems (SlimPipe's activation
offloading, controllable-memory pipelines) show residency is an axis
*orthogonal* to the schedule kind. This module makes it one:

  * ``ResidencyPolicy`` — the declarative contract: which ops release a
    local stash slot and restore it before the backward, how the spilled
    unit is moved (partner swap / host copy / re-forward), what device
    bytes a released unit still retains, and the cap formulas the
    planner's cap search needs.
  * ``spill(base, cap, release_op, restore_op)`` — the one cap-driven
    stream rewrite (re-homed from ``schedule._balance``): whenever the
    local stash would exceed ``cap`` (including the in-flight restore
    transient), the unit whose backward is farthest away (the newest
    held) is released right after a forward and restored just before its
    own backward. Every policy shares it, so ``bpipe_swap`` stays
    bit-identical to the pre-refactor BPipe streams and the new policies
    inherit exactly the same spill discipline.
  * ``POLICIES`` / ``register`` — the registry that extends the op set:
    ``plan._plan_stream`` derives dependency edges, ``plan`` derives the
    accounting handlers, and the simulator derives pricing handlers from
    the registered policies, so registering one here is the ONE step
    that makes a residency mechanism compilable, simulable, executable
    and plannable (docs/memory.md).

Built-in policies: ``none``, ``bpipe_swap`` (here), ``host_offload``
(``repro.memory.offload``), ``selective_recompute``
(``repro.memory.recompute``).
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Callable, Dict, Optional

from repro.core import schedule as sched
from repro.core.notation import Notation
from repro.core.schedule import B, EVICT, F, Instr, LOAD, Stream

#: Residency mechanisms (``ResidencyPolicy.mechanism``):
#:   none      - the unit stays in the local store until its B
#:   swap      - released units land on the BPipe partner stage (EVICT/LOAD)
#:   host      - released units are copied to host memory (OFFLOAD/FETCH)
#:   recompute - released units free their residuals; the restore re-runs
#:               the forward from the retained boundary input (DROP/RECOMPUTE)
MECHANISMS = ("none", "swap", "host", "recompute")


def spill(base: Stream, cap: int, release_op: str, restore_op: str) -> Stream:
    """The cap-driven residency rewrite over any F/B stream: whenever the
    local stash would exceed ``cap`` (including the in-flight restore
    transient), the unit whose backward is farthest away (the newest
    held) is released right after a forward, and restored just before
    its own backward. Units are (mb, chunk, sl) — a sequence-sliced
    stream's slices spill independently, like any other unit. With
    ``(release_op, restore_op) = (EVICT, LOAD)`` this is exactly BPipe's
    continuous balancing (``schedule._balance``)."""
    released: set = set()
    held: list = []                   # local stash, oldest first
    out: Stream = []
    for pos, ins in enumerate(base):
        key = (ins.mb, ins.chunk, ins.sl)
        if ins.op == F:
            # Will the next backward's restore land while this F's output
            # is still held? Then budget one extra slot for it.
            nxt = base[pos + 1] if pos + 1 < len(base) else None
            pending = 1 if (nxt is not None and nxt.op == B
                            and (nxt.mb, nxt.chunk, nxt.sl) in released) \
                else 0
            # Proactively make room *before* computing the forward.
            while len(held) + 1 + pending > cap:
                vmb, vchunk, vsl = held.pop()   # newest held
                out.append(Instr(release_op, vmb, vchunk, vsl))
                released.add((vmb, vchunk, vsl))
            out.append(ins)
            held.append(key)
        else:  # B
            if key in released:
                out.append(Instr(restore_op, ins.mb, ins.chunk, ins.sl))
                released.discard(key)
                held.append(key)
            out.append(ins)
            held.remove(key)
    return out


def residency_cap(p: int, v: int = 1) -> int:
    """The default local-stash bound a capped residency policy balances
    to: the BPipe bound (the same per-device number the paper's pairing
    achieves), generalized to v chunks."""
    return sched.bpipe_cap(p) if v <= 1 else sched.bpipe_interleaved_cap(p, v)


def residency_cap_roof(p: int, m: int, v: int = 1) -> int:
    """Cap above which the rewrite degenerates to the base schedule
    (stage-0 1F1B peak) — bounds the planner's cap search."""
    if v <= 1:
        return max(min(p, m), 2)
    return max(sched.interleaved_peak(p, m, 0, v), 2)


def _no_retained(n: Notation, attention: str, v: int) -> float:
    return 0.0


@dataclasses.dataclass(frozen=True)
class ResidencyPolicy:
    """Everything the system needs to know about one residency mechanism.

    Fields:
      name:        registry key (``ScheduleSpec.residency``).
      release_op / restore_op:
                   the op pair the spill rewrite emits (None for the
                   ``none`` policy). ``plan`` derives dependency edges
                   (release depends on the unit's own F, restore on its
                   release) and the stash/spill accounting from these.
      mechanism:   how a released unit is realized — "swap" (partner
                   store), "host" (D2H/H2D copy), "recompute" (free the
                   residuals, re-forward at restore). Drives the
                   simulator's pricing handler and the executor's store
                   operation for the op pair.
      default_cap: ``(p, v) -> int`` local-stash bound the rewrite
                   balances to when the spec does not override it.
      cap_roof:    ``(p, m, v) -> int`` cap above which the rewrite is a
                   no-op (planner cap-search clamp).
      retained_bytes:
                   ``(n, attention, v) -> float`` device bytes one
                   released unit STILL occupies (recompute keeps the
                   boundary input it re-forwards from; swap/host keep
                   nothing locally) — ``memory_model`` charges it.
      moves_data:  release/restore copy the unit's bytes over a link
                   (False for recompute: the restore costs FLOPs, not
                   bandwidth).
    """
    name: str
    release_op: Optional[str] = None
    restore_op: Optional[str] = None
    mechanism: str = "none"
    default_cap: Optional[Callable[[int, int], int]] = None
    cap_roof: Optional[Callable[[int, int, int], int]] = None
    retained_bytes: Callable[[Notation, str, int], float] = _no_retained

    def __post_init__(self):
        if self.mechanism not in MECHANISMS:
            raise ValueError(
                f"{self.name}: unknown mechanism {self.mechanism!r}; "
                f"one of {MECHANISMS}")
        if self.active and (self.release_op is None or self.restore_op is None
                            or self.default_cap is None
                            or self.cap_roof is None):
            raise ValueError(
                f"{self.name}: active policies need release_op/restore_op "
                f"and default_cap/cap_roof — the rewrite and the planner's "
                f"cap search depend on all four")

    @property
    def active(self) -> bool:
        """Does this policy rewrite streams at all?"""
        return self.mechanism != "none"

    @property
    def swap(self) -> bool:
        return self.mechanism == "swap"

    @property
    def moves_data(self) -> bool:
        """Release/restore copy bytes over a link (vs. re-running FLOPs)."""
        return self.mechanism in ("swap", "host")

    def rewrite(self, base: Stream, cap: int) -> Stream:
        """Insert this policy's release/restore ops into a base stream,
        keeping the local stash within ``cap``."""
        if not self.active:
            return list(base)
        return spill(base, cap, self.release_op, self.restore_op)


# ---------------------------------------------------------------------------
# The registry — op-set extension point
# ---------------------------------------------------------------------------
POLICIES: Dict[str, ResidencyPolicy] = {}

# op -> policy maps, rebuilt on every register/unregister; ``plan`` and
# the simulator derive dependency edges, accounting and pricing handlers
# from these, so a registered policy's ops are immediately dispatchable.
RELEASE_OPS: Dict[str, ResidencyPolicy] = {}
RESTORE_OPS: Dict[str, ResidencyPolicy] = {}


def _rebuild_derived() -> None:
    RELEASE_OPS.clear()
    RESTORE_OPS.clear()
    for pol in POLICIES.values():
        if not pol.active:
            continue
        RELEASE_OPS[pol.release_op] = pol
        RESTORE_OPS[pol.restore_op] = pol


def _clear_plan_cache() -> None:
    # Deferred AND guarded: policies register while repro.core.plan may
    # still be mid-import (plan imports this module at its top).
    plan = sys.modules.get("repro.core.plan")
    if plan is not None and hasattr(plan, "compile_plan"):
        plan.compile_plan.cache_clear()


def register(pol: ResidencyPolicy, replace: bool = False) -> ResidencyPolicy:
    """Register a residency policy. Its ops become compilable (dependency
    edges + accounting in ``plan``), simulable (priced by mechanism) and
    plannable (``planner.space`` cap ladder) with no interpreter edits."""
    if pol.name in POLICIES and not replace:
        raise ValueError(f"residency policy {pol.name!r} already registered")
    if pol.active:
        for other in POLICIES.values():
            if other.name == pol.name or not other.active:
                continue
            if {pol.release_op, pol.restore_op} \
                    & {other.release_op, other.restore_op}:
                raise ValueError(
                    f"{pol.name}: ops collide with {other.name}")
    POLICIES[pol.name] = pol
    _rebuild_derived()
    _clear_plan_cache()
    return pol


def unregister(name: str) -> None:
    """Remove a registered policy (tests / plugin teardown)."""
    POLICIES.pop(name, None)
    _rebuild_derived()
    _clear_plan_cache()


def get(name: str) -> ResidencyPolicy:
    pol = POLICIES.get(name)
    if pol is None:
        raise ValueError(f"unknown residency policy {name!r}; "
                         f"registered: {sorted(POLICIES)}")
    return pol


NONE = register(ResidencyPolicy("none"))

#: The paper's mechanism, re-homed: EVICT ships the newest held unit to
#: the paired acceptor stage, LOAD fetches it back ahead of its backward.
#: The balanced schedule kinds (bpipe / bpipe_interleaved) embed this
#: policy — their builders call ``spill`` with this op pair, and
#: ``ScheduleSpec`` normalizes their residency field to this name.
BPIPE_SWAP = register(ResidencyPolicy(
    "bpipe_swap", EVICT, LOAD, mechanism="swap",
    default_cap=residency_cap, cap_roof=residency_cap_roof))
