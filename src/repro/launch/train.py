"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50 --batch 8 --seq 128

On real hardware: drop --reduced, point --mesh at the production mesh
(the same sharding rules the dry-run validates are applied), and raise
--batch/--seq to the target shape. Checkpoints resume automatically.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.optim import adam
from repro.sharding import rules
from repro.train.steps import init_all, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the family (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--micro-batch", type=int, default=0,
                    help="paper's b: grad-accumulation microbatch (0=off)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none",
                    choices=["none", "attn", "full"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        global_batch=args.batch, micro_batch=args.micro_batch or args.batch,
        seq_len=args.seq, steps=args.steps,
        warmup_steps=max(args.steps // 20, 5), learning_rate=args.lr,
        remat=args.remat, seed=args.seed)

    params, opt = init_all(cfg, args.seed)
    start_step = 0
    if args.ckpt and os.path.exists(args.ckpt):
        state = ckpt.restore(args.ckpt, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start_step = int(opt.step)
        print(f"[resume] {args.ckpt} @ step {start_step}")

    step_fn = make_train_step(cfg, tcfg)
    dc = DataConfig(batch=args.batch, seq_len=args.seq, seed=args.seed)
    n_params = cfg.param_count()
    print(f"[train] {cfg.name}  ~{n_params/1e6:.0f}M params  "
          f"B={args.batch} s={args.seq} remat={args.remat} "
          f"devices={len(jax.devices())}")

    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dc, i).items()}
        params, opt, m = step_fn(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = (time.time() - t0) / max(i - start_step + 1, 1)
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"lr {float(m['lr']):.2e}  {dt:.2f}s/step", flush=True)
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt, {"params": params, "opt": opt})
    if args.ckpt:
        ckpt.save(args.ckpt, {"params": params, "opt": opt})
        print(f"[done] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
