"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import InputShape, base
from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.model import ENCODER_FRAMES
from repro.optim import adam


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, s = shape.global_batch, shape.seq_len
    n_text = s - (cfg.num_prefix_embeds if cfg.frontend == "vision" else 0)
    out = {"tokens": sds((B, n_text), jnp.int32),
           "labels": sds((B, n_text), jnp.int32)}
    if cfg.frontend == "vision":
        out["prefix_embeds"] = sds((B, cfg.num_prefix_embeds, cfg.d_model),
                                   jnp.float32)
    if cfg.is_encdec:
        out["enc_embeds"] = sds((B, ENCODER_FRAMES, cfg.d_model), jnp.float32)
    return out


def prefill_batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    out = train_batch_specs(cfg, shape)
    out.pop("labels")
    return out


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(M.init_params, cfg=cfg), jax.random.PRNGKey(0))


def opt_specs(params_spec):
    return jax.eval_shape(adam.init, params_spec)


def decode_state_specs(cfg: ModelConfig, shape: InputShape):
    return jax.eval_shape(
        lambda: M.init_decode_state(cfg, shape.global_batch, shape.seq_len))


def decode_input_specs(cfg: ModelConfig, shape: InputShape):
    out = {"token": sds((shape.global_batch,), jnp.int32),
           "pos": sds((), jnp.int32)}
    if cfg.is_encdec:
        out["enc_states"] = sds(
            (shape.global_batch, ENCODER_FRAMES, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Everything the step for this shape kind consumes (sans params)."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape),
                "state": decode_state_specs(cfg, shape)}
    if shape.kind == "decode":
        return {"state": decode_state_specs(cfg, shape),
                **decode_input_specs(cfg, shape)}
    raise ValueError(shape.kind)
