"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests/examples (requires the host-device-count
    XLA flag to have been set before jax init)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return compat.make_mesh((data, model), ("data", "model"))
