import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory / cost / collective artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single multi

Per combo this writes experiments/dryrun/<arch>__<shape>__<mesh>.json:
  * compile wall time, per-device memory analysis (args/outputs/temps),
  * raw cost_analysis (scan-body-once caveat — see launch/roofline.py),
  * roofline-extrapolated per-device FLOPs / HBM bytes / collective bytes
    from unrolled 1-block and 2-block variants (single-pod only),
  * the three roofline terms + dominant bottleneck.
"""
import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, shape_applicable
from repro.configs.base import TrainConfig
from repro.core import flops as flops_mod
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import adam
from repro.sharding import rules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _tree_shardings(mesh, specs):
    return jax.tree.map(lambda s: _ns(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# --- §Perf variants: each is (cfg overrides, tcfg overrides, cache strategy)
VARIANTS = {
    "baseline": ({}, {}, "heads"),
    "fused_xent": ({"fused_xent": True}, {}, "heads"),
    "remat_none": ({}, {"remat": "none"}, "heads"),
    "remat_full": ({}, {"remat": "full"}, "heads"),
    "cache_seq": ({}, {}, "seq"),
    "cache_auto": ({}, {}, "auto"),
    "moe_a2a": ({"moe_constrained": True}, {}, "heads"),
    "fused_xent+remat_full": ({"fused_xent": True}, {"remat": "full"}, "heads"),
    "fused_xent+moe_a2a": ({"fused_xent": True, "moe_constrained": True},
                           {}, "heads"),
    "bf16_scores": ({"attn_fp32": False}, {}, "heads"),
    "moe_fsdp": ({}, {}, "heads", "data"),
    "moe_fsdp+a2a": ({"moe_constrained": True}, {}, "heads", "data"),
    "bf16_scores+remat_none": ({"attn_fp32": False}, {"remat": "none"},
                               "heads"),
    "window1k": ({"block_pattern": ("local_attn",), "window_size": 1024},
                 {}, "heads"),  # quantifies the s^2-score traffic share
    # the paper's own axis: micro batch size (grad accumulation)
    "accum_b8": ({}, {"micro_batch": 8}, "heads"),
    # pad q heads to the model-axis multiple (+20% attn flops for qwen3)
    # to test the head-divisibility hypothesis for the prefill collectives
    "pad_heads48": ({"num_heads": 48}, {}, "heads"),
    "pad_heads48_mha": ({"num_heads": 48, "num_kv_heads": 48}, {}, "heads"),
    "accum_b8+remat_none": ({}, {"micro_batch": 8, "remat": "none"}, "heads"),
    "moe_fsdp+accum_b8": ({}, {"micro_batch": 8}, "heads", "data"),
    "moe_a2a+accum_b8": ({"moe_constrained": True}, {"micro_batch": 8},
                         "heads"),
}


def build_step(cfg, shape, mesh, tcfg: TrainConfig, cache_strategy="heads",
               moe_axis="model"):
    """Returns (fn, arg_specs, in_shardings) for this shape kind."""
    pspec = sp.param_specs(cfg)
    p_sh = _tree_shardings(mesh, rules.param_specs(pspec, mesh, moe_axis))
    if shape.kind == "train":
        batch = sp.train_batch_specs(cfg, shape)
        o_spec = sp.opt_specs(pspec)
        o_sh = jax.tree.map(
            lambda s: s, adam.AdamState(
                step=_ns(mesh, P()),
                m=_tree_shardings(mesh, rules.param_specs(pspec, mesh, moe_axis)),
                v=_tree_shardings(mesh, rules.param_specs(pspec, mesh, moe_axis))))
        b_sh = _tree_shardings(mesh, rules.batch_specs(batch, mesh))

        num_micro = max(1, shape.global_batch // tcfg.micro_batch) \
            if tcfg.micro_batch else 1

        def step(params, opt_state, b):
            if num_micro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: M.loss_fn(p, b, cfg, remat=tcfg.remat),
                    has_aux=True)(params)
            else:
                # paper's b-axis: microbatched gradient accumulation.
                # Live activations scale with micro_batch, not B.
                mb = {k: v.reshape((num_micro, -1) + v.shape[1:])
                      for k, v in b.items()}

                def acc(carry, bi):
                    g_sum, l_sum = carry
                    (l, _), g = jax.value_and_grad(
                        lambda p: M.loss_fn(p, bi, cfg, remat=tcfg.remat),
                        has_aux=True)(params)
                    return (jax.tree.map(jnp.add, g_sum, g), l_sum + l), None

                zeros = jax.tree.map(
                    lambda a: jnp.zeros(a.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(acc, (zeros, 0.0), mb)
                grads = jax.tree.map(lambda g: g / num_micro, grads)
                loss = loss / num_micro
                metrics = {"loss": loss, "aux": 0.0}
            params, opt_state, om = adam.update(params, grads, opt_state, tcfg)
            return params, opt_state, dict(metrics, **om)

        return (step, (pspec, o_spec, batch), (p_sh, o_sh, b_sh),
                (p_sh, o_sh, None))

    if shape.kind == "prefill":
        batch = sp.prefill_batch_specs(cfg, shape)
        state = sp.decode_state_specs(cfg, shape)
        b_sh = _tree_shardings(mesh, rules.batch_specs(batch, mesh))
        s_sh = _tree_shardings(mesh, rules.cache_specs(state, mesh,
                                                       cache_strategy, cfg))

        def step(params, b, state):
            logits, state, _ = M.prefill(params, b, cfg, state)
            return logits, state

        return step, (pspec, batch, state), (p_sh, b_sh, s_sh), None

    # decode
    state = sp.decode_state_specs(cfg, shape)
    dec_in = sp.decode_input_specs(cfg, shape)
    s_sh = _tree_shardings(mesh, rules.cache_specs(state, mesh,
                                                   cache_strategy, cfg))
    ba = rules.batch_axes(mesh)
    tok_sh = _ns(mesh, rules.legalize(P(ba), dec_in["token"].shape, mesh))
    pos_sh = _ns(mesh, P())
    args = [pspec, state, dec_in["token"], dec_in["pos"]]
    shards = [p_sh, s_sh, tok_sh, pos_sh]
    if cfg.is_encdec:
        args.append(dec_in["enc_states"])
        shards.append(_ns(mesh, rules.legalize(
            P(ba, None, None), dec_in["enc_states"].shape, mesh)))

        def step(params, state, token, pos, enc_states):
            logits, state = M.decode_step(params, token, pos, state, cfg,
                                          enc_states=enc_states)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return nxt, state
    else:
        def step(params, state, token, pos):
            logits, state = M.decode_step(params, token, pos, state, cfg)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return nxt, state

    return step, tuple(args), tuple(shards), None


def lower_combo(cfg, shape, mesh, tcfg, cache_strategy="heads",
                moe_axis="model") -> Dict:
    rules.RELOCATIONS.clear()
    fn, args, in_sh, out_sh = build_step(cfg, shape, mesh, tcfg,
                                         cache_strategy, moe_axis)
    relocs = sorted({(t, d, -1 if d2 is None else d2)
                     for t, _, d, d2, _ in rules.RELOCATIONS})
    if relocs:
        print(f"WARN sharding relocations (collective hazard, see "
              f"EXPERIMENTS HC-5): {relocs}", flush=True)
    t0 = time.time()
    jitted = (jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
              if out_sh is not None else jax.jit(fn, in_shardings=in_sh))
    with compat.set_mesh(mesh):  # enables with_sharding_constraint(P(...))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    coll = rl.collective_bytes(txt)
    return {
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "cost_raw": {"flops": float(ca.get("flops", 0.0)),
                     "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
        "collective_bytes_raw": coll,
        "hlo_collective_ops": {
            k: txt.count(f" {k}") for k in rl.COLLECTIVES},
    }


def variant_cfg(cfg, k: int):
    """Unrolled k-block variant (full dims) for roofline extraction."""
    kw = dict(num_layers=len(cfg.block_pattern) * k, scan_blocks=False)
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def effective_blocks(cfg) -> float:
    pat = len(cfg.block_pattern)
    return cfg.num_layers / pat


def roofline_combo(cfg, shape, mesh, tcfg, cache_strategy="heads",
                   moe_axis="model") -> Dict:
    """Extrapolated per-device roofline terms via 1- vs 2-block unrolls."""
    res = {}
    for k in (1, 2):
        r = lower_combo(variant_cfg(cfg, k), shape, mesh, tcfg,
                        cache_strategy, moe_axis)
        res[k] = {"flops": r["cost_raw"]["flops"],
                  "bytes": r["cost_raw"]["bytes_accessed"],
                  "coll": sum(r["collective_bytes_raw"].values()),
                  **{f"coll_{kk}": v
                     for kk, v in r["collective_bytes_raw"].items()}}
    n = effective_blocks(cfg)
    ext = rl.extrapolate(res[1], res[2], n)
    terms = rl.RooflineTerms(
        flops=ext["flops"], bytes_hbm=ext["bytes"],
        bytes_collective=ext["coll"], chips=mesh.devices.size)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        # serve_step does not rerun the encoder (enc_states are an input)
        model_flops = flops_mod.model_flops_fwd(cfg, b, 1,
                                                include_encoder=False)
    elif shape.kind == "prefill":
        model_flops = flops_mod.model_flops_fwd(cfg, b, s)
    else:
        model_flops = flops_mod.model_flops_train(cfg, b, s)
    mf_dev = model_flops / mesh.devices.size
    return {
        "per_block_points": res,
        "extrapolated": ext,
        "terms": terms.to_dict(),
        "model_flops_per_device": mf_dev,
        "useful_fraction": (mf_dev / ext["flops"]) if ext["flops"] else None,
        "roofline_mfu": terms.mfu(mf_dev),
    }


def run_one(arch: str, shape_name: str, mesh_kind: str,
            *, with_roofline: bool, out_dir: str, force=False,
            variant: str = "baseline") -> Optional[str]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return None
    spec = VARIANTS[variant]
    cfg_over, tcfg_over, cache_strategy = spec[0], spec[1], spec[2]
    moe_axis = spec[3] if len(spec) > 3 else "model"
    cfg = dataclasses.replace(cfg, **cfg_over)
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        return path
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    # micro_batch=0 disables grad accumulation (single-shot baseline);
    # the accum_* variants set the paper's b explicitly.
    tcfg = TrainConfig(global_batch=shape.global_batch,
                       seq_len=shape.seq_len, remat="attn", micro_batch=0)
    tcfg = dataclasses.replace(tcfg, **tcfg_over)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "chips": int(mesh.devices.size),
           "params": cfg.param_count()}
    rec["full"] = lower_combo(cfg, shape, mesh, tcfg, cache_strategy,
                              moe_axis)
    if with_roofline and mesh_kind == "single":
        rec["roofline"] = roofline_combo(cfg, shape, mesh, tcfg,
                                         cache_strategy, moe_axis)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", nargs="*", default=["single", "multi"],
                    choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    archs = args.arch or (list(ASSIGNED) if args.all else ["qwen1.5-0.5b"])
    shapes = args.shape or (list(INPUT_SHAPES) if args.all else ["train_4k"])

    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in args.mesh:
                t0 = time.time()
                try:
                    path = run_one(arch, shape_name, mesh_kind,
                                   with_roofline=not args.no_roofline,
                                   out_dir=args.out, force=args.force,
                                   variant=args.variant)
                except Exception as e:  # noqa: BLE001 — report & continue
                    print(f"FAIL {arch} {shape_name} {mesh_kind}: {e!r}",
                          flush=True)
                    continue
                if path is None:
                    print(f"SKIP {arch} {shape_name} {mesh_kind} "
                          f"(not applicable)", flush=True)
                else:
                    with open(path) as f:
                        rec = json.load(f)
                    dom = rec.get("roofline", {}).get("terms", {}).get(
                        "dominant", "-")
                    print(f"OK   {arch} {shape_name} {mesh_kind} "
                          f"compile={rec['full']['t_compile_s']}s "
                          f"temp={rec['full']['memory']['temp_bytes']/2**30:.2f}GiB "
                          f"dominant={dom} ({time.time()-t0:.0f}s)",
                          flush=True)


if __name__ == "__main__":
    main()
