"""Schedule auto-planner CLI — the front door to the repo.

    PYTHONPATH=src python -m repro.launch.plan --config llama_65b --hbm-gb 80
    PYTHONPATH=src python -m repro.launch.plan --config gpt3_96b \
        --attention recompute --top 12
    PYTHONPATH=src python -m repro.launch.plan --config qwen3-14b \
        --trace step.trace.json --trace-b 2

Prints the ranked plan table (every candidate, including OOM-pruned and
break-even-rejected rows with the required_stage_gain bar they failed)
and a one-line recommendation per attention arm. Costs come from the
paper's Table 5 measurements for its two models, an analytic roofline
guess otherwise, or a real executor trace via --trace.
"""
from __future__ import annotations

import argparse
import sys

from repro.configs import get_config, list_configs
from repro.core.notation import (A100_PEAK_BF16, NVLINK_BW,
                                 TPU_V5E_ICI_BW, TPU_V5E_PEAK_BF16,
                                 from_model)
from repro.planner import (SearchSpace, calibrate, cost_model_for,
                           plan_config, report)

LINKS = {"nvlink": NVLINK_BW, "ici": TPU_V5E_ICI_BW}
CHIPS = {"a100": A100_PEAK_BF16, "tpu_v5e": TPU_V5E_PEAK_BF16}


def resolve_config(name: str):
    """Accept registry names and their underscore aliases
    (gpt3_96b -> gpt3-96b), per the docs' CLI examples."""
    for cand in (name, name.replace("_", "-"), name.replace("_", ".")):
        try:
            return get_config(cand)
        except KeyError:
            continue
    raise SystemExit(f"unknown --config {name!r}; known: {list_configs()}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="rank pipeline-schedule plans for a config")
    ap.add_argument("--config", required=True,
                    help="model config name (underscores ok: llama_65b)")
    ap.add_argument("--hbm-gb", type=float, default=80.0,
                    help="per-device HBM budget (default: A100-80G)")
    ap.add_argument("--p", type=int, default=8, help="pipeline stages")
    ap.add_argument("--t", type=int, default=4, help="tensor-parallel size")
    ap.add_argument("--B", type=int, default=128, help="global batch")
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--attention", default="",
                    choices=["", "none", "recompute", "flash"],
                    help="restrict to one attention arm")
    ap.add_argument("--residency", default="", nargs="*",
                    help="residency policies to search on plain kinds "
                         "(default: none host_offload selective_recompute; "
                         "balanced kinds always carry bpipe_swap)")
    ap.add_argument("--link", default="nvlink", choices=sorted(LINKS),
                    help="evictor<->acceptor link for BPipe traffic")
    ap.add_argument("--host-bw", type=float, default=0.0,
                    help="host D2H/H2D bandwidth in GB/s for host_offload "
                         "(default: PCIe gen4 x16)")
    ap.add_argument("--chip", default="a100", choices=sorted(CHIPS))
    ap.add_argument("--v", type=int, nargs="*", default=[2, 4],
                    help="interleaved chunks-per-device to search")
    ap.add_argument("--depth", type=int, nargs="*", default=[1, 2],
                    help="transfer-overlap depths to search for "
                         "residency-managed plans (in-flight moves per "
                         "channel; depth 1 = serialized classic)")
    ap.add_argument("--seq-chunks", type=int, nargs="*", default=[1],
                    help="sequence slices per microbatch to search, e.g. "
                         "--seq-chunks 1 2 4 (docs/longcontext.md; c > 1 "
                         "only on kinds with a sliced builder and seq "
                         "lengths c divides; default: unsliced only)")
    ap.add_argument("--vocab-parallel", type=int, nargs="*", default=[1],
                    help="vocab-parallel degrees to search, e.g. "
                         "--vocab-parallel 1 2 4 (docs/memory.md 'Vocab "
                         "accounting'; vp > 1 scatters the embedding/head/"
                         "logits spike over vp boundary stages for "
                         "per-microbatch collective traffic; degrees > p "
                         "are skipped; default: unscattered only)")
    ap.add_argument("--overhead", type=float, default=0.0,
                    help="fractional BPipe overhead inflating break-even")
    ap.add_argument("--exhaustive", action="store_true",
                    help="simulate every feasible candidate instead of the "
                         "branch-and-bound search (same recommendation, "
                         "slower — docs/planner.md 'Search performance')")
    ap.add_argument("--verbose", action="store_true",
                    help="print search statistics: verdict counts and the "
                         "compile-cache hit/miss/bind counters")
    ap.add_argument("--top", type=int, default=16,
                    help="table rows to print (0 = all)")
    ap.add_argument("--csv", action="store_true",
                    help="machine-readable rows instead of the table")
    ap.add_argument("--spec-json", action="store_true",
                    help="also print each arm's recommended plan as a "
                         "ScheduleSpec JSON line (hand it to the "
                         "executor/simulator via ScheduleSpec.from_dict)")
    ap.add_argument("--perfetto", default="",
                    help="write the recommended plan's simulated timeline "
                         "as a Perfetto/Chrome trace JSON (stage tracks, "
                         "channel tracks, HBM counter tracks — open in "
                         "ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default="",
                    help="write the recommended plan's step metrics "
                         "(bubble%%, stalls, channel occupancy, per-stage "
                         "HBM peaks) as JSON")
    ap.add_argument("--trace", default="",
                    help="Chrome-trace JSON from executor step(trace=True); "
                         "calibrates Tf/Tb instead of Table5/analytic costs")
    ap.add_argument("--trace-b", type=int, default=1,
                    help="micro batch size the trace ran at")
    ap.add_argument("--trace-v", type=int, default=1,
                    help="chunks per device in the traced run")
    ap.add_argument("--trace-c", type=int, default=1,
                    help="sequence slices per microbatch in the traced run")
    ap.add_argument("--trace-attention", default="none",
                    choices=["none", "recompute", "flash"],
                    help="attention arm the traced run used (other arms "
                         "are scaled by the analytic time factors)")
    args = ap.parse_args(argv)

    cfg = resolve_config(args.config)
    n = from_model(cfg, b=1, s=args.seq, B=args.B, p=args.p, t=args.t)
    attentions = ((args.attention,) if args.attention
                  else ("none", "recompute", "flash"))
    kw = {}
    if args.residency:
        from repro.memory import policy as respol
        valid = sorted(n for n, p in respol.POLICIES.items() if not p.swap)
        for name in args.residency:
            if name not in valid:
                # bpipe_swap is registered but not a plain-kind residency
                # (it is the balanced kinds' built-in mechanism)
                raise SystemExit(f"unknown --residency {name!r}; known: "
                                 f"{valid}")
        kw["residencies"] = tuple(args.residency)
    search = SearchSpace(attentions=attentions, vs=tuple(args.v),
                         depths=tuple(args.depth),
                         seq_chunkses=tuple(args.seq_chunks),
                         vocab_parallels=tuple(args.vocab_parallel), **kw)

    if args.trace:
        events = calibrate.load_chrome_trace(args.trace)
        costs = calibrate.fit_trace(events, v=args.trace_v, b=args.trace_b,
                                    seq_chunks=args.trace_c)
        cost = calibrate.TraceCostModel(costs, peak_per_chip=CHIPS[args.chip],
                                        attention=args.trace_attention)
        print(f"# calibrated from {args.trace}: Tf={costs.Tf:.4g}s "
              f"Tb={costs.Tb:.4g}s ({costs.samples} events)")
    else:
        cost = cost_model_for(cfg, CHIPS[args.chip])

    if args.verbose:
        from repro.core import plan as plan_mod
        plan_mod.compile_cache_stats(reset=True)
    ranked = plan_config(n, cfg, args.hbm_gb * 2**30, cost=cost,
                         search=search, link_bw=LINKS[args.link],
                         overhead=args.overhead,
                         host_bw=(args.host_bw * 1e9 if args.host_bw
                                  else None),
                         exhaustive=args.exhaustive)
    if args.verbose:
        from collections import Counter

        from repro.core import plan as plan_mod
        counts = Counter(p.verdict for p in ranked)
        simulated = sum(1 for p in ranked if p.makespan > 0)
        stats = plan_mod.compile_cache_stats()
        print(f"# search: {len(ranked)} enumerated, {simulated} simulated, "
              + ", ".join(f"{counts.get(k, 0)} {k}"
                          for k in ("ok", "reject", "pruned", "infeasible")))
        print(f"# compile cache: {stats['hits']} hits, "
              f"{stats['misses']} misses, {stats['binds']} depth-binds, "
              f"{stats['evictions']} evictions, size {stats['size']}"
              f"/{stats['maxsize']}")
    if args.csv:
        for row in report.csv_rows(ranked, "plan", cfg.name):
            print(row)
    else:
        print(f"# {cfg.name}: p={n.p} t={n.t} B={n.B} s={n.s} "
              f"hbm={args.hbm_gb:.0f}GiB link={args.link} "
              f"({len(ranked)} candidates)")
        print(report.format_table(ranked, top=args.top))
    for line in report.summarize(cfg.name, n, ranked):
        print(line)
    if args.perfetto or args.metrics_json:
        import json

        from repro.core import memory_model as mm
        from repro.core import plan as plan_mod
        from repro.core import simulator as SIM
        from repro.obs import Recorder
        from repro.obs import export as obs_export
        from repro.obs import metrics as obs_metrics
        from repro.planner.rank import recommend, sim_config_for
        best = recommend(ranked, args.attention or None)
        if best is None:
            print("# nothing to export: no feasible plan", file=sys.stderr)
        else:
            # Re-simulate the winning plan with a recorder attached —
            # the exact SimConfig rank priced it with — so the exported
            # timeline/metrics describe the plan the CLI recommended.
            rec = Recorder()
            simcfg = sim_config_for(n, best, cost, LINKS[args.link],
                                    args.host_bw * 1e9 if args.host_bw
                                    else None)
            res = SIM.simulate(simcfg, observer=rec)
            spec = simcfg.spec
            nb = n.replace(b=best.cand.b)
            counters = obs_metrics.hbm_timeline(
                rec.spans, plan_mod.compile_plan(spec).partner,
                mm.sliced_unit_bytes(nb, best.cand.attention, spec.v,
                                     spec.seq_chunks),
                retained_bytes=spec.policy.retained_bytes(
                    nb, best.cand.attention, spec.v),
                p=spec.p)
            if args.perfetto:
                obs_export.save_trace(rec.spans, args.perfetto,
                                      counters=counters)
                print(f"# wrote Perfetto trace: {args.perfetto} "
                      f"({len(rec.spans)} spans)")
            if args.metrics_json:
                met = obs_metrics.compute(
                    rec.spans, p=spec.p,
                    model_flops=cost.full_flops(n), t=n.t,
                    peak_flops=cost.peak_per_chip,
                    channel_stats=res.channels)
                with open(args.metrics_json, "w") as f:
                    json.dump({"config": cfg.name,
                               "spec": spec.to_dict(),
                               "metrics": met.to_dict(),
                               "hbm_peaks": obs_metrics.hbm_peaks(counters)},
                              f, indent=1)
                print(f"# wrote metrics JSON: {args.metrics_json}")
    if args.spec_json:
        import json
        from repro.planner.rank import arms_of, recommend
        for arm in arms_of(ranked) + [None]:
            best = recommend(ranked, arm)
            if best is None:
                continue
            print(json.dumps({
                "arm": arm or "overall", "b": best.cand.b,
                "attention": best.cand.attention,
                "spec": best.cand.spec(n.p).to_dict()}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
