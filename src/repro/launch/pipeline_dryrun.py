import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Pipeline-parallel dry-run of the paper's own configuration, at
production-mesh scale: GPT-3 96B (and LLaMA 65B) with the "model" axis
carrying p=16 pipeline stages (the paper's Fig. 2 16-way setup),
data-parallel over the remaining axes, with and without the BPipe
activation-offload pattern (pipeline/spmd.py).

    PYTHONPATH=src python -m repro.launch.pipeline_dryrun [--arch gpt3-96b]

Writes experiments/dryrun/pipeline__<arch>__<mesh>__<variant>.json with
collective-permute counts/bytes (the eviction hops) + memory analysis.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.pipeline.spmd import init_pipeline_params, make_spmd_train_loss

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run(arch: str, mesh_kind: str, bpipe: bool, *, p=16, B=128, s=2048,
        num_micro=None, out_dir=None):
    cfg = get_config(arch)
    assert cfg.num_layers % p == 0
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    # microbatches stream per data shard: num_micro must divide the
    # local batch (B / data-axes product)
    data = 1
    for a in mesh.axis_names:
        if a != "model":
            data *= mesh.shape[a]
    local_b = max(B // data, 1)
    num_micro = num_micro or local_b
    lossf = make_spmd_train_loss(cfg, mesh, p, num_micro=num_micro,
                                 bpipe_stash=bpipe)
    pshape = jax.eval_shape(
        lambda k: init_pipeline_params(k, cfg, p), jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((B, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, s), jnp.int32)}

    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jax.jit(jax.grad(lossf)).lower(pshape, batch)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = rl.collective_bytes(txt)
    rec = {
        "arch": arch, "mesh": mesh_kind, "p": p, "num_micro": num_micro,
        "bpipe_stash": bpipe, "t_compile_s": round(t_compile, 2),
        "memory": {"argument_bytes": ma.argument_size_in_bytes,
                   "temp_bytes": ma.temp_size_in_bytes},
        "collective_bytes": coll,
        "collective_permute_ops": txt.count(" collective-permute"),
    }
    out_dir = out_dir or os.path.abspath(OUT_DIR)
    os.makedirs(out_dir, exist_ok=True)
    name = f"pipeline__{arch}__{mesh_kind}__{'bpipe' if bpipe else '1f1b'}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"OK pipeline {arch} {mesh_kind} bpipe={bpipe} "
          f"compile={t_compile:.1f}s temp={ma.temp_size_in_bytes/2**30:.1f}GiB "
          f"cp_ops={rec['collective_permute_ops']} "
          f"cp_bytes={coll['collective-permute']/2**30:.2f}GiB", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=["gpt3-96b", "llama-65b"])
    ap.add_argument("--mesh", nargs="*", default=["single", "multi"])
    args = ap.parse_args()
    for arch in args.arch:
        for mesh_kind in args.mesh:
            for bpipe in (False, True):
                try:
                    run(arch, mesh_kind, bpipe)
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL pipeline {arch} {mesh_kind} bpipe={bpipe}: "
                          f"{e!r}", flush=True)


if __name__ == "__main__":
    main()
