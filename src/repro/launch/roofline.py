"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory     = HLO_bytes / (chips x 819 GB/s)
    collective = collective_bytes / (chips x 50 GB/s/link)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes
are parsed out of ``compiled.as_text()`` (operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).

CAVEAT + FIX: XLA's cost analysis counts a while/scan body ONCE, so a
scan-over-blocks model under-reports by ~num_blocks. launch/dryrun.py
therefore lowers two extra *unrolled* variants (1 block and 2 blocks,
full dims) and extrapolates:  total = base + per_block x n_blocks, where
per_block = cost(2 blocks) - cost(1 block) and base = cost(1) - per_block.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.core.notation import (TPU_V5E_HBM_BW, TPU_V5E_ICI_BW,
                                 TPU_V5E_PEAK_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# `%x = bf16[8,128,16]{2,1,0} all-gather(...)`  (also matches -start ops)
_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind output bytes (per device, post-SPMD HLO)."""
    out = {k: 0.0 for k in COLLECTIVES}
    seen_done = set()
    for m in _RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] += n * _DTYPE_BYTES[dtype]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float            # per device
    bytes_hbm: float        # per device
    bytes_collective: float  # per device
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / TPU_V5E_PEAK_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / TPU_V5E_HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_collective / TPU_V5E_ICI_BW

    @property
    def dominant(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """Optimistic (perfect-overlap) step time = max of the three."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def mfu(self, model_flops_per_device: float) -> float:
        return model_flops_per_device / (self.step_time * TPU_V5E_PEAK_BF16)

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "bytes_collective": self.bytes_collective, "chips": self.chips,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
        }


def extrapolate(cost1: Dict, cost2: Dict, n_blocks: int) -> Dict:
    """total = base + per_block * n_blocks from 1- and 2-block unrolled runs."""
    out = {}
    keys = set(cost1) | set(cost2)
    for k in keys:
        c1, c2 = cost1.get(k, 0.0), cost2.get(k, 0.0)
        per_block = max(c2 - c1, 0.0)
        base = max(c1 - per_block, 0.0)
        out[k] = base + per_block * n_blocks
    return out
