"""gemma2-9b — dense, alternating local/global attention, logit softcaps.

[arXiv:2408.00118] 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
"""
from repro.configs.base import ModelConfig, ATTN, LOCAL

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256_000,
    block_pattern=(LOCAL, ATTN),
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_kind="gelu",
    tie_embeddings=True,
)
