"""granite-moe-1b-a400m — MoE 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L d_model=1024 16H (GQA kv=8)
per-expert d_ff=512 vocab=49155.
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=49_155,
    block_pattern=(ATTN,),
    moe=MoEConfig(num_experts=32, top_k=8, d_ff=512),
    mlp_kind="swiglu",
    tie_embeddings=True,
)
