"""recurrentgemma-2b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent.

[arXiv:2402.19427] (Griffin / RecurrentGemma). 26L d_model=2560 10H
(GQA kv=1) d_ff=7680 vocab=256000, local attention window 2048.
"""
from repro.configs.base import ModelConfig, RGLRU, LOCAL

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=(RGLRU, RGLRU, LOCAL),
    window_size=2048,
    rnn_width=2560,
    mlp_kind="swiglu",
    tie_embeddings=True,
)
