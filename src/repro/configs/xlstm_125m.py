"""xlstm-125m — sLSTM + mLSTM blocks. [arXiv:2405.04517]

12L d_model=768 4H d_ff=0 vocab=50304. Pattern 1 mLSTM : 1 sLSTM.
d_ff=0: xLSTM blocks carry their own up/down projections, no separate FFN.
"""
from repro.configs.base import ModelConfig, MLSTM, SLSTM

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=(MLSTM, SLSTM),
    chunk_size=256,
    norm="layernorm",
)
