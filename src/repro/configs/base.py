"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The layer
stack is described by ``block_pattern`` — a repeating tuple of sublayer
kinds — so heterogeneous stacks (gemma2 local/global alternation,
recurrentgemma's RGLRU:attn 2:1, xLSTM's mLSTM/sLSTM mix) all flow
through one scan-based implementation (models/blocks.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Sublayer kinds usable in block_pattern. Each entry denotes the temporal
# mixer of one layer; an FFN (dense or MoE per config) follows each layer
# unless d_ff == 0.
ATTN = "attn"            # global causal attention
LOCAL = "local_attn"     # sliding-window causal attention
RGLRU = "rglru"          # Griffin-style gated linear recurrent unit block
MLSTM = "mlstm"          # xLSTM matrix-memory cell (chunkwise parallel)
SLSTM = "slstm"          # xLSTM scalar-memory cell (sequential scan)

MIXER_KINDS = (ATTN, LOCAL, RGLRU, MLSTM, SLSTM)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    shared_expert: bool = False    # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    source: str                    # citation for the configuration
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense FFN hidden (0 = no FFN)
    vocab_size: int

    head_dim: int = 0              # 0 -> d_model // num_heads
    block_pattern: Tuple[str, ...] = (ATTN,)

    # --- attention options -------------------------------------------------
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen1.5
    attn_softcap: float = 0.0      # gemma2 attention-logit softcap
    final_softcap: float = 0.0     # gemma2 final-logit softcap
    window_size: int = 0           # sliding window for LOCAL layers
    rope_theta: float = 10_000.0
    attn_impl: str = "reference"   # reference | recompute | flash

    # --- FFN / MoE ----------------------------------------------------------
    mlp_kind: str = "swiglu"       # swiglu | gelu
    moe: Optional[MoEConfig] = None

    # --- recurrent (RG-LRU / xLSTM) ------------------------------------------
    rnn_width: int = 0             # RG-LRU recurrent width (0 -> d_model)
    conv_width: int = 4            # temporal conv width in recurrent blocks
    chunk_size: int = 256          # mLSTM chunkwise block length

    # --- enc-dec / modality frontend -----------------------------------------
    encoder_layers: int = 0        # >0 => encoder-decoder (whisper)
    frontend: str = "none"         # none | audio | vision (stub embeddings)
    num_prefix_embeds: int = 0     # vision patch tokens prepended (vlm)

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False

    # --- lowering strategy -----------------------------------------------------
    # scan_blocks=True iterates pattern blocks with lax.scan (O(1) HLO in
    # depth). False unrolls them — used by launch/roofline.py to extract
    # exact per-block cost terms (XLA cost_analysis counts a scan body once).
    scan_blocks: bool = True

    # --- perf levers (EXPERIMENTS.md §Perf; defaults = paper-faithful baseline)
    # fused_xent: masked-reduce cross-entropy that never gathers the
    # vocab-sharded logits (vs. baseline take_along_axis gather).
    fused_xent: bool = False
    # constrain MoE dispatch buffers to (batch->data, experts->model) so
    # GSPMD lowers one clean all-to-all instead of gather chains.
    moe_constrained: bool = False
    # attention score/softmax precision: True = fp32 (paper-faithful:
    # its exp-(7) chain is exactly this upcast); False = bf16 scores
    # (halves the s^2 HBM traffic; production systems do this when the
    # flash kernel isn't in play).
    attn_fp32: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        for k in self.block_pattern:
            assert k in MIXER_KINDS, k
        assert self.num_heads % self.num_kv_heads == 0, (
            self.num_heads, self.num_kv_heads)

    # ---- derived ------------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """The mixer kind of every (decoder) layer, pattern repeated."""
        pat = self.block_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer does *global* attention over the full sequence
        (the assignment's criterion for running long_500k)."""
        return ATTN not in self.layer_kinds()

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        attn = d * hd * (n_q + 2 * n_kv) + n_q * hd * d
        if self.qkv_bias:
            attn += hd * (n_q + 2 * n_kv)
        ffn_dense = 0
        if self.d_ff:
            ffn_dense = (3 if self.mlp_kind == "swiglu" else 2) * d * self.d_ff
        rglru = 0
        if RGLRU in self.block_pattern:
            w = self.rnn_width
            rglru = 2 * d * w + w * d + self.conv_width * w + 2 * w * w + 2 * w
        total = 0
        for kind in self.layer_kinds():
            if kind in (ATTN, LOCAL):
                total += attn
            elif kind == RGLRU:
                total += rglru
            elif kind in (MLSTM, SLSTM):
                total += 4 * d * n_q * hd + n_q * hd * d + 3 * n_q * hd
            if self.moe is not None:
                e = self.moe
                total += d * e.num_experts  # router
                total += e.num_experts * 3 * d * e.d_ff
                if e.shared_expert:
                    total += 3 * d * e.d_ff
            elif self.d_ff:
                total += ffn_dense
            total += 2 * d  # norms
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn_dense + 2 * d)
            total += self.num_layers * (attn + 2 * d)  # cross-attn in decoder
        return total

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-scale variant of the same family (<=2 layers,
        d_model<=512, <=4 experts), per the assignment."""
        pat = self.block_pattern
        n_layers = max(len(pat), 2)
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        # Families with a decoupled head_dim (gemma2-style wide heads:
        # head_dim != d_model/num_heads) keep their width *ratio* at
        # smoke scale — rebinding to d_model//n_heads silently changed
        # what shape family the smoke test exercises. Rounded to the
        # nearest even width: RoPE splits the head in half.
        head_dim = d_model // n_heads
        if self.head_dim * self.num_heads != self.d_model:
            ratio = self.head_dim * self.num_heads / self.d_model
            head_dim = max(2, 2 * round(head_dim * ratio / 2))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2), d_ff=128)
        kw = dict(
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=0 if self.d_ff == 0 else 2 * d_model,
            vocab_size=min(self.vocab_size, 512),
            rnn_width=0 if self.rnn_width == self.d_model else min(self.rnn_width, d_model),
            window_size=min(self.window_size, 32) if self.window_size else 0,
            chunk_size=16,
            moe=moe,
            encoder_layers=2 if self.encoder_layers else 0,
            num_prefix_embeds=min(self.num_prefix_embeds, 4),
        )
        kw.update(overrides)
        new = dataclasses.replace(self, **kw)
        object.__setattr__(new, "rnn_width", kw["rnn_width"] or d_model)
        return new


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Run-level configuration (paper notation: B, b, p, t)."""
    global_batch: int = 128
    micro_batch: int = 1            # paper's `b`
    seq_len: int = 2048             # paper's `s`
    pp: int = 8                     # paper's `p` (pipeline stages)
    tp: int = 4                     # paper's `t` (tensor parallel)
    dp: int = 1
    schedule: str = "1f1b"          # gpipe | 1f1b | bpipe
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    steps: int = 300
    seed: int = 0
    remat: str = "none"             # none | attn | full  (paper's recompute arms)
