"""whisper-small — encoder-decoder audio model. [arXiv:2212.04356]

12L (enc) + 12L (dec) d_model=768 12H d_ff=3072 vocab=51865. The
mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs`` feeds precomputed frame embeddings (batch, frames, d).
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    block_pattern=(ATTN,),
    encoder_layers=12,
    frontend="audio",
    mlp_kind="gelu",
    norm="layernorm",
)
