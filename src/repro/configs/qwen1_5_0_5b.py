"""qwen1.5-0.5b — dense MHA, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    block_pattern=(ATTN,),
    qkv_bias=True,
    mlp_kind="swiglu",
    tie_embeddings=True,
)

# Sliding-window variant used to demonstrate the dense-with-SWA long_500k
# path (the base model is full attention and skips long_500k).
import dataclasses
from repro.configs.base import LOCAL

CONFIG_SWA = dataclasses.replace(
    CONFIG,
    name="qwen1.5-0.5b-swa",
    block_pattern=(LOCAL,),
    window_size=4096,
)
