"""GPT-3 96B — the paper's own evaluation model (paper Table 2).

h=9984 a=104 s=2048 l=80 B=128, vocab ~51200 (GPT-2 BPE padded).
GELU FFN with d_ff = 4h, learned-position-free (we use RoPE as the
positional scheme; the paper's analysis is positional-scheme agnostic).
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="gpt3-96b",
    family="dense",
    source="paper Table 2 (Huang et al. 2024)",
    num_layers=80,
    d_model=9984,
    num_heads=104,
    num_kv_heads=104,
    head_dim=96,
    d_ff=4 * 9984,
    vocab_size=51_200,
    block_pattern=(ATTN,),
    mlp_kind="gelu",
    norm="layernorm",
    qkv_bias=True,
)
