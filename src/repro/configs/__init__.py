"""Config registry: every assigned architecture + the paper's own models.

``get_config(name)`` is the single entry point used by the launcher
(``--arch <id>``), tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, TrainConfig  # noqa: F401
from repro.configs.longcontext import (LONG_CONTEXT,  # noqa: F401
                                       LongContextCase, get_case)

from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma_2b
from repro.configs.qwen3_14b import CONFIG as _qwen3_14b
from repro.configs.gemma2_9b import CONFIG as _gemma2_9b
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4_scout
from repro.configs.xlstm_125m import CONFIG as _xlstm_125m
from repro.configs.qwen1_5_32b import CONFIG as _qwen1_5_32b
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen1_5_0_5b, CONFIG_SWA as _qwen1_5_0_5b_swa
from repro.configs.whisper_small import CONFIG as _whisper_small
from repro.configs.internvl2_1b import CONFIG as _internvl2_1b
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite_moe
from repro.configs.gpt3_96b import CONFIG as _gpt3_96b
from repro.configs.llama_65b import CONFIG as _llama_65b

# The ten architectures assigned to this paper (public pool).
ASSIGNED = (
    "recurrentgemma-2b",
    "qwen3-14b",
    "gemma2-9b",
    "llama4-scout-17b-a16e",
    "xlstm-125m",
    "qwen1.5-32b",
    "qwen1.5-0.5b",
    "whisper-small",
    "internvl2-1b",
    "granite-moe-1b-a400m",
)

_REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _recurrentgemma_2b, _qwen3_14b, _gemma2_9b, _llama4_scout,
        _xlstm_125m, _qwen1_5_32b, _qwen1_5_0_5b, _qwen1_5_0_5b_swa,
        _whisper_small, _internvl2_1b, _granite_moe,
        _gpt3_96b, _llama_65b,
    )
}


def list_configs():
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_configs()}")
    return _REGISTRY[name]


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """The assignment's applicability rules (skips recorded in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False  # full-attention archs skip 500k decode
    if cfg.is_encdec and shape.name == "long_500k":
        return False  # 500k-token decode has no audio use-case
    return True
