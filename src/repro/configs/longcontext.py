"""Long-context variants of the paper's evaluation models.

The paper runs GPT-3-96B and LLaMA-65B at s=2048; sequence-sliced
schedules (``ScheduleSpec.seq_chunks``, docs/longcontext.md) only start
to matter when the sequence — and with it the 2sbh/t boundary stash and
the attention quadratic — dominates memory. These variants pin the
32k/128k shapes the long-context sweep and the planner CLI use, so
"llama_65b_32k" means the same thing everywhere.

A variant is a *run shape*, not a new architecture: the ModelConfig is
the paper's card unchanged; only Notation-level knobs (s, B, and the
chunk ladder worth searching) move. Global batch shrinks as s grows to
keep tokens-per-batch in the paper's regime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class LongContextCase:
    """One long-context planning shape: base model + sequence override."""
    name: str
    model: str                       # base config registry name
    seq_len: int
    global_batch: int
    p: int = 8
    t: int = 4
    # chunk ladder the sweep searches (1 first: unsliced baseline)
    seq_chunkses: Tuple[int, ...] = (1, 2, 4, 8)

    def notation(self, cfg, b: int = 1):
        # deferred: core.notation imports configs.base, so a module-level
        # import here would close an import cycle through the package init
        from repro.core.notation import from_model
        return from_model(cfg, b=b, s=self.seq_len, B=self.global_batch,
                          p=self.p, t=self.t)


LONG_CONTEXT: Dict[str, LongContextCase] = {
    c.name: c for c in (
        # 32k: unsliced 1F1B needs ~95-117 GiB/stage — over an A100-80G —
        # while c >= 2 fits; 128k needs t=16 on top (c=1 is 100+ GiB
        # even with recompute residency, c >= 4 fits).
        LongContextCase("llama-65b-32k", "llama-65b", 32_768, 32, p=16,
                        t=8),
        LongContextCase("llama-65b-128k", "llama-65b", 131_072, 16, p=16,
                        t=16),
        LongContextCase("gpt3-96b-32k", "gpt3-96b", 32_768, 32, p=16,
                        t=8),
        LongContextCase("gpt3-96b-128k", "gpt3-96b", 131_072, 16, p=16,
                        t=16),
    )
}


def list_cases():
    return sorted(LONG_CONTEXT)


def get_case(name: str) -> LongContextCase:
    for cand in (name, name.replace("_", "-")):
        if cand in LONG_CONTEXT:
            return LONG_CONTEXT[cand]
    raise KeyError(f"unknown long-context case {name!r}; "
                   f"known: {list_cases()}")
