"""internvl2-1b — VLM: InternViT (stub) + Qwen2-0.5B-class LM backbone.

[arXiv:2404.16821] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The vision encoder + projector is a STUB: ``input_specs`` provides
precomputed patch embeddings prepended to the token stream.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    block_pattern=(ATTN,),
    qkv_bias=True,
    frontend="vision",
    num_prefix_embeds=256,   # one image tile worth of patch tokens
    mlp_kind="swiglu",
    tie_embeddings=True,
)
