"""qwen1.5-32b — dense MHA (kv=heads), QKV bias. [hf:Qwen/Qwen1.5-0.5B card]"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152_064,
    block_pattern=(ATTN,),
    qkv_bias=True,
    mlp_kind="swiglu",
)
