"""LLaMA 65B — the paper's other evaluation model.

Standard LLaMA-65B card: 80L h=8192 64 heads, d_ff=22016 (8/3·h rounded),
s=2048, B=128 in the paper's runs. SwiGLU FFN => the paper's §3.1 point
that LLaMA FFN FLOPs (3 matmuls to 8/3·h) equal GPT-3's 16bsh².
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="llama-65b",
    family="dense",
    source="paper §3.1 (Huang et al. 2024); arXiv:2302.13971",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=64,
    head_dim=128,
    d_ff=22016,
    vocab_size=32_000,
    block_pattern=(ATTN,),
    mlp_kind="swiglu",
)
