"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert) vocab=202048, early-fusion multimodal (frontend
stubbed per assignment; text path exercised here).
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,  # all FFN capacity is in the MoE
    vocab_size=202_048,
    block_pattern=(ATTN,),
    moe=MoEConfig(num_experts=16, top_k=1, d_ff=8192, shared_expert=True),
    mlp_kind="swiglu",
)
