"""The paper's §4 performance-estimation method (eq. 2-4).

Core identity (eq. 3):
    MFU(b) = F * MFU_stage(b) / ((1 + b/B * (p-1)) * F_stage)

and the speedup predictor (eq. 4):
    MFU(x)/MFU(y) = (B + y(p-1))/(B + x(p-1)) * MFU_stage(x)/MFU_stage(y)

which needs only two cheap single-stage measurements — the paper's
recipe for deciding whether implementing BPipe is worth it at all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.notation import Notation


def bubble_factor(n: Notation) -> float:
    """(B/b + p - 1) / (B/b): fraction of time inflated by pipeline bubbles
    under the paper's idealization (uniform stages, negligible comm)."""
    m = n.num_micro
    return (m + n.p - 1) / m


def mfu_from_T(n: Notation, F: float, T: float, P: float) -> float:
    """Eq. 2: MFU given per-microbatch fwd+bwd stage time T(b)."""
    m = n.num_micro
    return F / (P * (m + n.p - 1) * T)


def stage_T_from_mfu(n: Notation, F_stage: float, mfu_stage: float, P_stage: float) -> float:
    """Invert MFU_stage(b) = b * F_stage / (P_stage * B * T(b)) -> T(b).

    F_stage is the full-global-batch FLOPs of one stage ((b/B)*F_stage per
    microbatch); P_stage is the peak of the *stage's* device group (t
    chips) — the paper reuses the symbol P for both scopes.
    """
    return (n.b / n.B) * F_stage / (P_stage * mfu_stage)


def mfu_model(n: Notation, F: float, F_stage: float, mfu_stage: float) -> float:
    """Eq. 3: whole-pipeline MFU from single-stage MFU.

    The paper's P is per-"device" in MFU_stage (t chips) but whole-cluster
    in MFU (p*t chips); with P_tot = p * P_stage the algebra gives
        MFU = F * MFU_stage / (p * F_stage * (1 + b/B*(p-1)))
    and with the uniform split F_stage = F/p this is the clean
        MFU = MFU_stage / (1 + b/B * (p-1))   — stage efficiency divided
    by the bubble factor.
    """
    return F * mfu_stage / (n.p * (1.0 + n.b / n.B * (n.p - 1)) * F_stage)


def speedup(n: Notation, bx: int, by: int,
            mfu_stage_x: float, mfu_stage_y: float) -> float:
    """Eq. 4: predicted MFU(x)/MFU(y) when micro batch goes y -> x."""
    return ((n.B + by * (n.p - 1)) / (n.B + bx * (n.p - 1))
            * (mfu_stage_x / mfu_stage_y))


def required_stage_gain(n: Notation, bx: int, by: int,
                        overhead: float = 0.0) -> float:
    """Beyond-paper corollary of eq. 4: the minimum single-stage MFU
    *ratio* MFU_stage(bx)/MFU_stage(by) for BPipe-at-bx to break even
    against plain-1F1B-at-by, i.e. the bubble penalty of the larger
    micro batch (optionally inflated by a fractional BPipe overhead).

    Usable before ANY implementation work: if your kernel suite's
    throughput gain from by->bx is below this number, BPipe cannot win
    (this is exactly why the paper's LLaMA rows are negative: required
    gain at b=2->4, p=8, B=128 is 1.099, measured stage gain was 1.056).
    """
    need = (n.B + bx * (n.p - 1)) / (n.B + by * (n.p - 1))
    return need * (1.0 + overhead)


def fit_stage_mfu(points, k_default: float = 0.25):
    """Fit the saturating single-stage throughput curve
        MFU_stage(b) = M * b / (b + k)
    through measured (b, MFU_stage) points and return it as a callable.

    This is the paper's "two cheap single-stage measurements" recipe made
    programmatic: two points pin (M, k) exactly (the fit is linear in
    (1/b, 1/MFU) space: 1/MFU = 1/M + (k/M)/b); more points are fit by
    least squares; a single point borrows ``k_default`` for the shape.
    The planner interpolates/extrapolates stage MFU to unmeasured micro
    batch sizes with it — feasibility pruning keeps the extrapolation
    honest (b's beyond the measured range are usually OOM anyway).
    """
    pts = sorted(dict(points).items())
    assert pts and all(b > 0 and mfu > 0 for b, mfu in pts), pts
    if len(pts) == 1:
        b0, m0 = pts[0]
        M = m0 * (b0 + k_default) / b0
        k = k_default
    else:
        xs = [1.0 / b for b, _ in pts]
        ys = [1.0 / mfu for _, mfu in pts]
        nn = len(pts)
        sx, sy = sum(xs), sum(ys)
        sxx = sum(x * x for x in xs)
        sxy = sum(x * y for x, y in zip(xs, ys))
        denom = nn * sxx - sx * sx
        slope = (nn * sxy - sx * sy) / denom      # = k/M
        inter = (sy - slope * sx) / nn            # = 1/M
        if inter <= 0 or slope < 0:
            # Degenerate (non-saturating) data: fall back to a flat curve
            # at the largest measurement — conservative for BPipe, which
            # only wins through stage gain.
            top = max(mfu for _, mfu in pts)
            return lambda b: top
        M, k = 1.0 / inter, slope / inter
    return lambda b: M * b / (b + k)


# ---------------------------------------------------------------------------
# Paper data (Tables 3 and 5) for the reproduction benchmarks.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PaperRow:
    exp_id: int
    model: str
    b: int
    bpipe: bool
    attention: str
    mfu: float          # Table 3: whole-model MFU [%]
    mfu_stage: float    # Table 5: single-stage MFU [%]


PAPER_ROWS = (
    PaperRow(1, "llama-65b", 1, False, "none", 45.3, 51.1),
    PaperRow(2, "llama-65b", 2, False, "recompute", 46.0, 54.5),
    PaperRow(3, "llama-65b", 4, True, "recompute", 42.7, 57.6),
    PaperRow(4, "llama-65b", 1, False, "flash", 47.8, 53.6),
    PaperRow(5, "llama-65b", 2, False, "flash", 49.2, 58.6),
    PaperRow(6, "llama-65b", 4, True, "flash", 44.0, 61.9),
    PaperRow(7, "gpt3-96b", 1, False, "recompute", 34.0, 37.8),
    PaperRow(8, "gpt3-96b", 2, True, "recompute", 45.8, 55.2),
    PaperRow(9, "gpt3-96b", 1, False, "flash", 52.0, 57.7),
    PaperRow(10, "gpt3-96b", 2, True, "flash", 51.7, 62.4),
)


def paper_row(exp_id: int) -> PaperRow:
    return PAPER_ROWS[exp_id - 1]


def predicted_vs_observed(n: Notation, x_id: int, y_id: int) -> Dict[str, float]:
    """Apply eq. 4 to a pair of paper experiments; e.g. (8, 7) reproduces
    the paper's 1.39 predicted vs 1.35 observed."""
    rx, ry = paper_row(x_id), paper_row(y_id)
    pred = speedup(n, rx.b, ry.b, rx.mfu_stage, ry.mfu_stage)
    obs = rx.mfu / ry.mfu
    return {"predicted": pred, "observed": obs,
            "gap_pct": 100.0 * (pred - obs) / obs}
