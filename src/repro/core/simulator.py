"""Discrete-event simulator of pipeline schedules (GPipe / 1F1B / BPipe,
plain and interleaved) — a handler set over ``plan.run``.

Validates the paper's closed-form estimates against explicit timelines and
quantifies what the paper *ignores* (its §4: "We also temporarily ignore
the overhead introduced by the BPipe technique"): eviction/load traffic
that fails to overlap shows up here as real makespan.

Model:
  * per-stage compute: Tf(b) forward, Tb(b) backward per microbatch; for
    interleaved kinds each of the v chunks does 1/v of the work, so a
    chunk's F costs Tf/v and its B costs Tb/v,
  * p2p boundary transfer between adjacent *virtual* stages: t_p2p
    (charged on every compiled dependency edge whose ``dep_hop`` is set —
    including the device p-1 -> device 0 wraparound between chunks),
  * EVICT/LOAD: async copies on the evictor<->acceptor link
    (bytes / pair_bw * hops); serialized per link; LOAD(mb, chunk) must
    finish before B(mb, chunk) starts. LOAD prefetch is issued one
    *chunk-level* F+B slot ((Tf+Tb)/v) ahead of the backward it feeds,
    so interleaved BPipe load-stall is charged at chunk granularity, not
    a whole-device slot (pinned by tests/test_plan.py),
  * residency ops (``repro.memory``): OFFLOAD/FETCH are async copies on
    the per-device host link (bytes / d2h_bw resp. h2d_bw, serialized
    per direction; FETCH prefetched like LOAD and stalling B the same
    way), DROP is free bookkeeping, and RECOMPUTE occupies the stage's
    compute frontier for one chunk-level forward (Tf/v) — the FLOPs bill
    of recomputation. Pricing handlers are derived from the policy
    registry's mechanism field, so a newly registered policy's ops are
    priced without edits here.

The schedule itself — streams, dependency edges, device hops, partner
map — comes precompiled from ``plan.compile_plan``; this module only
prices instructions. Makespans across plain/interleaved/BPipe/residency
variants are directly comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import plan as P
from repro.core.schedule import B, F
from repro.memory import policy as respol


@dataclasses.dataclass
class SimConfig:
    """Cost knobs plus the schedule variant to price.

    Preferred: ``SimConfig(spec=ScheduleSpec(...), Tf=..., Tb=...)``.
    Legacy: the (p, m, kind, v, cap) knobs construct the spec — kept as a
    deprecation shim; ``spec`` wins when both are given (it re-syncs the
    legacy fields so old readers of ``cfg.p``/``cfg.kind`` stay correct).
    """
    p: int = 0
    m: int = 0                  # microbatches
    Tf: float = 0.0             # forward time per microbatch per device
    Tb: float = 0.0             # backward time (typically 2*Tf)
    t_p2p: float = 0.0          # stage-boundary activation transfer
    evict_bytes: float = 0.0    # bytes per residency move (EVICT/OFFLOAD/..)
    pair_bw: float = float("inf")
    pair_hops: int = 1
    d2h_bw: float = float("inf")   # host link, device -> host (OFFLOAD)
    h2d_bw: float = float("inf")   # host link, host -> device (FETCH)
    kind: str = "1f1b"
    v: int = 2                  # chunks per device (interleaved kinds only)
    cap: Optional[int] = None   # stash-cap override (balanced / residency)
    residency: str = "none"     # residency policy (plain kinds)
    spec: Optional[P.ScheduleSpec] = None

    def __post_init__(self):
        if self.spec is not None:
            self.p, self.m = self.spec.p, self.spec.m
            self.kind, self.cap = self.spec.kind, self.spec.cap
            self.residency = self.spec.residency
            if self.spec.interleaved:
                self.v = self.spec.v

    def to_spec(self) -> P.ScheduleSpec:
        """The schedule variant this config prices."""
        if self.spec is not None:
            return self.spec
        # residency goes into the constructor directly: building a
        # residency-less spec first would null a cap override (no active
        # policy -> no cap) before the replace could re-activate it
        return P.ScheduleSpec(self.kind, self.p, self.m, v=self.v,
                              cap=self.cap, residency=self.residency)


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: List[float]           # per-stage compute-busy time
    load_stall: float           # total time backwards waited on restores
    timeline: Dict[int, List]   # (op, mb, chunk, start, end) per stage
    move_time: float = 0.0      # summed residency-op time (link occupancy
                                # for swap/host moves, re-forward time for
                                # recompute) — the overhead exposure that
                                # breaks equal-makespan ties in the planner

    @property
    def bubble_fraction(self) -> float:
        total = self.makespan * len(self.busy)
        return 1.0 - sum(self.busy) / total


def _simulate(cfg: SimConfig) -> SimResult:
    spec = cfg.to_spec()
    schedule = P.compile_plan(spec)
    p, v = spec.p, spec.v
    # One full microbatch of F work per device is Tf regardless of v:
    # each chunk holds 1/v of the device's layers.
    tf, tb = cfg.Tf / v, cfg.Tb / v
    t_move = (cfg.evict_bytes / cfg.pair_bw) * cfg.pair_hops \
        if cfg.evict_bytes else 0.0
    t_d2h = cfg.evict_bytes / cfg.d2h_bw if cfg.evict_bytes else 0.0
    t_h2d = cfg.evict_bytes / cfg.h2d_bw if cfg.evict_bytes else 0.0
    partner = schedule.partner

    t_stage = {i: 0.0 for i in range(p)}    # stage compute frontier
    done: Dict[P.DepKey, float] = {}        # (op, stage, mb, chunk) -> end
    link_free: Dict[tuple, float] = {}      # pair link serialization
    busy = {i: 0.0 for i in range(p)}
    state = {"stall": 0.0, "last_b": 0.0, "move": 0.0}
    timeline: Dict[int, List] = {i: [] for i in range(p)}

    def finish(i, ins, start_t, end_t):
        timeline[i].append((ins.op, ins.mb, ins.chunk, start_t, end_t))

    def on_f(i, ins):
        if ins.dep is None:
            dep = 0.0
        else:
            dep = done.get(ins.dep)
            if dep is None:
                return P.BLOCKED
        hop = cfg.t_p2p if ins.dep_hop else 0.0
        start_t = max(t_stage[i], dep + hop)
        end_t = start_t + tf
        done[ins.done_key] = end_t
        busy[i] += tf
        t_stage[i] = end_t
        finish(i, ins, start_t, end_t)

    def on_b(i, ins):
        dep = done.get(ins.dep)
        if dep is None:
            return P.BLOCKED
        hop = cfg.t_p2p if ins.dep_hop else 0.0
        start_t = max(t_stage[i], dep + hop)
        for rop in _stall_ops:     # data-moving restores gate the backward
            le = done.get((rop, i, ins.mb, ins.chunk))
            if le is not None and le > start_t:
                state["stall"] += le - start_t
                start_t = le
        end_t = start_t + tb
        done[ins.done_key] = end_t
        state["last_b"] = max(state["last_b"], end_t)
        busy[i] += tb
        t_stage[i] = end_t
        finish(i, ins, start_t, end_t)

    def on_evict(i, ins):
        # async: starts when F(mb, chunk) finished and the link frees
        pair = (min(i, partner[i]), max(i, partner[i]))
        start_t = max(done[ins.dep], link_free.get(pair, 0.0))
        end_t = start_t + t_move
        done[ins.done_key] = end_t
        state["move"] += t_move
        link_free[pair] = end_t
        finish(i, ins, start_t, end_t)

    def on_load(i, ins):
        # async prefetch, issued one chunk-level F+B slot ahead of the
        # backward it feeds (overlaps that compute window)
        pair = (min(i, partner[i]), max(i, partner[i]))
        issue = max(0.0, t_stage[i] - tf - tb)
        start_t = max(issue, done[ins.dep], link_free.get(pair, 0.0))
        end_t = start_t + t_move
        done[ins.done_key] = end_t
        state["move"] += t_move
        link_free[pair] = end_t
        finish(i, ins, start_t, end_t)

    def on_offload(i, ins):
        # async D2H copy on the device's host link, serialized per
        # direction; starts when F(mb, chunk) finished
        key = ("d2h", i)
        start_t = max(done[ins.dep], link_free.get(key, 0.0))
        end_t = start_t + t_d2h
        done[ins.done_key] = end_t
        state["move"] += t_d2h
        link_free[key] = end_t
        finish(i, ins, start_t, end_t)

    def on_fetch(i, ins):
        # async H2D prefetch, same chunk-level issue window as LOAD
        key = ("h2d", i)
        issue = max(0.0, t_stage[i] - tf - tb)
        start_t = max(issue, done[ins.dep], link_free.get(key, 0.0))
        end_t = start_t + t_h2d
        done[ins.done_key] = end_t
        state["move"] += t_h2d
        link_free[key] = end_t
        finish(i, ins, start_t, end_t)

    def on_drop(i, ins):
        # freeing residuals is bookkeeping — no time, no link
        t = done[ins.dep]
        done[ins.done_key] = t
        finish(i, ins, t, t)

    def on_recompute(i, ins):
        # re-run the chunk's forward ON the compute frontier: the FLOPs
        # bill of recomputation the paper's recompute arms pay
        start_t = max(t_stage[i], done[ins.dep])
        end_t = start_t + tf
        done[ins.done_key] = end_t
        state["move"] += tf
        busy[i] += tf
        t_stage[i] = end_t
        finish(i, ins, start_t, end_t)

    # Pricing handlers by registered policy mechanism: swap ops ride the
    # pair link, host ops the per-device host link, recompute ops the
    # compute frontier. A policy registered by a plugin is priced here
    # with no simulator edits.
    handlers = {F: on_f, B: on_b}
    _mech_release = {"swap": on_evict, "host": on_offload,
                     "recompute": on_drop}
    _mech_restore = {"swap": on_load, "host": on_fetch,
                     "recompute": on_recompute}
    for op, pol in respol.RELEASE_OPS.items():
        handlers[op] = _mech_release[pol.mechanism]
    for op, pol in respol.RESTORE_OPS.items():
        handlers[op] = _mech_restore[pol.mechanism]
    _stall_ops = tuple(op for op, pol in respol.RESTORE_OPS.items()
                       if pol.moves_data)

    P.run(schedule.streams, handlers)
    makespan = max(max(t_stage.values()), state["last_b"])
    return SimResult(makespan=makespan,
                     busy=[busy[i] for i in range(p)],
                     load_stall=state["stall"], timeline=timeline,
                     move_time=state["move"])


# Public entry point. The dispatch loop itself lives in ``plan.run`` —
# this module contributes only the pricing handlers above.
simulate = _simulate


def mfu_from_sim(res: SimResult, model_flops: float, p: int, t: int,
                 peak_flops: float) -> float:
    """Observed-throughput MFU over the simulated step."""
    return model_flops / (res.makespan * p * t * peak_flops)


def ideal_makespan(cfg: SimConfig) -> float:
    """The paper's eq-2 idealization: (m + p - 1) * (Tf + Tb)."""
    return (cfg.m + cfg.p - 1) * (cfg.Tf + cfg.Tb)


def interleaved_ideal_makespan(cfg: SimConfig) -> float:
    """Megatron interleaved idealization: the pipeline ramp shrinks to
    (p - 1)/v flush units, so makespan ~= (m + (p - 1)/v)(Tf + Tb)."""
    return (cfg.m + (cfg.p - 1) / cfg.v) * (cfg.Tf + cfg.Tb)
