"""Discrete-event simulator of pipeline schedules (GPipe / 1F1B / BPipe,
plain and interleaved).

Validates the paper's closed-form estimates against explicit timelines and
quantifies what the paper *ignores* (its §4: "We also temporarily ignore
the overhead introduced by the BPipe technique"): eviction/load traffic
that fails to overlap shows up here as real makespan.

Model:
  * per-stage compute: Tf(b) forward, Tb(b) backward per microbatch; for
    interleaved kinds each of the v chunks does 1/v of the work, so a
    chunk's F costs Tf/v and its B costs Tb/v,
  * p2p boundary transfer between adjacent *virtual* stages: t_p2p
    (charged whenever the producing virtual stage lives on a different
    device, which for p > 1 is every hop — including the device p-1 ->
    device 0 wraparound between chunks),
  * EVICT/LOAD: async copies on the evictor<->acceptor link
    (bytes / pair_bw * hops); serialized per link; LOAD(mb, chunk) must
    finish before B(mb, chunk) starts.

All bookkeeping is keyed (stage, mb, chunk): F of chunk c at virtual
stage vs = c*p + s depends on virtual stage vs-1 — which may be a chunk
on the same device — and B of vs depends on vs+1, so interleaved and
BPipe makespans are directly comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import schedule as sched
from repro.core.schedule import B, EVICT, F, LOAD


@dataclasses.dataclass
class SimConfig:
    p: int
    m: int                      # microbatches
    Tf: float                   # forward time per microbatch per device
    Tb: float                   # backward time (typically 2*Tf)
    t_p2p: float = 0.0          # stage-boundary activation transfer
    evict_bytes: float = 0.0    # bytes per EVICT/LOAD
    pair_bw: float = float("inf")
    pair_hops: int = 1
    kind: str = "1f1b"
    v: int = 2                  # chunks per device (interleaved kinds only)
    cap: Optional[int] = None   # BPipe-family stash-cap override


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: List[float]           # per-stage compute-busy time
    load_stall: float           # total time backwards waited on LOADs
    timeline: Dict[int, List]   # (op, mb, chunk, start, end) per stage

    @property
    def bubble_fraction(self) -> float:
        total = self.makespan * len(self.busy)
        return 1.0 - sum(self.busy) / total


def simulate(cfg: SimConfig) -> SimResult:
    p = cfg.p
    v = cfg.v if cfg.kind in sched.INTERLEAVED else 1
    nv = p * v
    # One full microbatch of F work per device is Tf regardless of v:
    # each chunk holds 1/v of the device's layers.
    tf, tb = cfg.Tf / v, cfg.Tb / v
    streams = sched.build(cfg.kind, p, cfg.m, v, cfg.cap)
    partner = {}
    for a, b_ in sched.bpipe_pairs(p):
        partner[a] = b_
        partner[b_] = a
    t_move = (cfg.evict_bytes / cfg.pair_bw) * cfg.pair_hops \
        if cfg.evict_bytes else 0.0

    idx = {i: 0 for i in range(p)}          # next instruction pointer
    t_stage = {i: 0.0 for i in range(p)}    # stage compute frontier
    f_done: Dict[tuple, float] = {}         # (stage, mb, chunk) -> fwd end
    b_done: Dict[tuple, float] = {}
    evict_end: Dict[tuple, float] = {}      # (stage, mb, chunk) -> EVICT end
    load_end: Dict[tuple, float] = {}
    link_free: Dict[tuple, float] = {}      # pair link serialization
    busy = {i: 0.0 for i in range(p)}
    stall = 0.0
    timeline: Dict[int, List] = {i: [] for i in range(p)}

    remaining = sum(len(s) for s in streams.values())
    while remaining:
        progressed = False
        for i in range(p):
            while idx[i] < len(streams[i]):
                ins = streams[i][idx[i]]
                key = (i, ins.mb, ins.chunk)
                vs = sched.virtual_stage(i, ins.chunk, p)
                if ins.op == F:
                    if vs == 0:
                        dep = 0.0
                    else:
                        pi, pc = (vs - 1) % p, (vs - 1) // p
                        dep = f_done.get((pi, ins.mb, pc))
                        if dep is None:
                            break
                    hop = cfg.t_p2p if (vs > 0 and (vs - 1) % p != i) else 0.0
                    start_t = max(t_stage[i], dep + hop)
                    end_t = start_t + tf
                    f_done[key] = end_t
                    busy[i] += tf
                    t_stage[i] = end_t
                elif ins.op == B:
                    if vs == nv - 1:
                        dep = f_done.get(key)
                        hop = 0.0
                    else:
                        ni, nc = (vs + 1) % p, (vs + 1) // p
                        dep = b_done.get((ni, ins.mb, nc))
                        hop = cfg.t_p2p if ni != i else 0.0
                    if dep is None:
                        break
                    start_t = max(t_stage[i], dep + hop)
                    le = load_end.get(key)
                    if le is not None and le > start_t:
                        stall += le - start_t
                        start_t = le
                    end_t = start_t + tb
                    b_done[key] = end_t
                    busy[i] += tb
                    t_stage[i] = end_t
                elif ins.op == EVICT:
                    # async: starts when F(mb, chunk) finished and the link
                    # frees
                    pair = (min(i, partner[i]), max(i, partner[i]))
                    start_t = max(f_done[key], link_free.get(pair, 0.0))
                    end_t = start_t + t_move
                    evict_end[key] = end_t
                    link_free[pair] = end_t
                else:  # LOAD
                    # async prefetch, issued one F+B slot ahead of the
                    # backward it feeds (overlaps that compute window)
                    pair = (min(i, partner[i]), max(i, partner[i]))
                    issue = max(0.0, t_stage[i] - tf - tb)
                    start_t = max(issue, evict_end[key],
                                  link_free.get(pair, 0.0))
                    end_t = start_t + t_move
                    load_end[key] = end_t
                    link_free[pair] = end_t
                timeline[i].append((ins.op, ins.mb, ins.chunk, start_t, end_t))
                idx[i] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            raise RuntimeError("schedule deadlock")
    makespan = max(max(t_stage.values()),
                   max(b_done.values(), default=0.0))
    return SimResult(makespan=makespan,
                     busy=[busy[i] for i in range(p)],
                     load_stall=stall, timeline=timeline)


def mfu_from_sim(res: SimResult, model_flops: float, p: int, t: int,
                 peak_flops: float) -> float:
    """Observed-throughput MFU over the simulated step."""
    return model_flops / (res.makespan * p * t * peak_flops)


def ideal_makespan(cfg: SimConfig) -> float:
    """The paper's eq-2 idealization: (m + p - 1) * (Tf + Tb)."""
    return (cfg.m + cfg.p - 1) * (cfg.Tf + cfg.Tb)


def interleaved_ideal_makespan(cfg: SimConfig) -> float:
    """Megatron interleaved idealization: the pipeline ramp shrinks to
    (p - 1)/v flush units, so makespan ~= (m + (p - 1)/v)(Tf + Tb)."""
    return (cfg.m + (cfg.p - 1) / cfg.v) * (cfg.Tf + cfg.Tb)
