"""Discrete-event simulator of pipeline schedules (GPipe / 1F1B / BPipe).

Validates the paper's closed-form estimates against explicit timelines and
quantifies what the paper *ignores* (its §4: "We also temporarily ignore
the overhead introduced by the BPipe technique"): eviction/load traffic
that fails to overlap shows up here as real makespan.

Model:
  * per-stage compute: Tf(b) forward, Tb(b) backward per microbatch,
  * p2p boundary transfer between adjacent stages: t_p2p (can be 0),
  * EVICT/LOAD: async copies on the evictor<->acceptor link
    (bytes / pair_bw * hops); serialized per link; LOAD(mb) must finish
    before B(mb) starts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import schedule as sched
from repro.core.schedule import B, EVICT, F, LOAD


@dataclasses.dataclass
class SimConfig:
    p: int
    m: int                      # microbatches
    Tf: float                   # forward time per microbatch per stage
    Tb: float                   # backward time (typically 2*Tf)
    t_p2p: float = 0.0          # stage-boundary activation transfer
    evict_bytes: float = 0.0    # bytes per EVICT/LOAD
    pair_bw: float = float("inf")
    pair_hops: int = 1
    kind: str = "1f1b"


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: List[float]           # per-stage compute-busy time
    load_stall: float           # total time backwards waited on LOADs
    timeline: Dict[int, List]   # (op, mb, start, end) per stage

    @property
    def bubble_fraction(self) -> float:
        total = self.makespan * len(self.busy)
        return 1.0 - sum(self.busy) / total


def simulate(cfg: SimConfig) -> SimResult:
    streams = sched.build(cfg.kind, cfg.p, cfg.m)
    partner = {}
    for a, b_ in sched.bpipe_pairs(cfg.p):
        partner[a] = b_
        partner[b_] = a
    t_move = (cfg.evict_bytes / cfg.pair_bw) * cfg.pair_hops \
        if cfg.evict_bytes else 0.0

    idx = {i: 0 for i in range(cfg.p)}          # next instruction pointer
    t_stage = {i: 0.0 for i in range(cfg.p)}    # stage compute frontier
    f_done: Dict[tuple, float] = {}             # (stage, mb) -> fwd end
    b_done: Dict[tuple, float] = {}
    evict_end: Dict[tuple, float] = {}          # (stage, mb) -> EVICT end
    load_end: Dict[tuple, float] = {}
    link_free: Dict[tuple, float] = {}          # pair link serialization
    busy = {i: 0.0 for i in range(cfg.p)}
    stall = 0.0
    timeline: Dict[int, List] = {i: [] for i in range(cfg.p)}

    remaining = sum(len(s) for s in streams.values())
    while remaining:
        progressed = False
        for i in range(cfg.p):
            while idx[i] < len(streams[i]):
                ins = streams[i][idx[i]]
                if ins.op == F:
                    dep = 0.0 if i == 0 else f_done.get((i - 1, ins.mb))
                    if dep is None:
                        break
                    start_t = max(t_stage[i], dep + cfg.t_p2p)
                    end_t = start_t + cfg.Tf
                    f_done[(i, ins.mb)] = end_t
                    busy[i] += cfg.Tf
                    t_stage[i] = end_t
                elif ins.op == B:
                    dep = (f_done.get((i, ins.mb)) if i == cfg.p - 1
                           else b_done.get((i + 1, ins.mb)))
                    if dep is None:
                        break
                    start_t = max(t_stage[i], dep + cfg.t_p2p)
                    le = load_end.get((i, ins.mb))
                    if le is not None and le > start_t:
                        stall += le - start_t
                        start_t = le
                    end_t = start_t + cfg.Tb
                    b_done[(i, ins.mb)] = end_t
                    busy[i] += cfg.Tb
                    t_stage[i] = end_t
                elif ins.op == EVICT:
                    # async: starts when F(mb) finished and the link frees
                    pair = (min(i, partner[i]), max(i, partner[i]))
                    start_t = max(f_done[(i, ins.mb)], link_free.get(pair, 0.0))
                    end_t = start_t + t_move
                    evict_end[(i, ins.mb)] = end_t
                    link_free[pair] = end_t
                else:  # LOAD
                    # async prefetch, issued one F+B slot ahead of the
                    # backward it feeds (overlaps that compute window)
                    pair = (min(i, partner[i]), max(i, partner[i]))
                    issue = max(0.0, t_stage[i] - cfg.Tf - cfg.Tb)
                    start_t = max(issue, evict_end[(i, ins.mb)],
                                  link_free.get(pair, 0.0))
                    end_t = start_t + t_move
                    load_end[(i, ins.mb)] = end_t
                    link_free[pair] = end_t
                timeline[i].append((ins.op, ins.mb, start_t, end_t))
                idx[i] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            raise RuntimeError("schedule deadlock")
    makespan = max(max(t_stage.values()),
                   max(b_done.values(), default=0.0))
    return SimResult(makespan=makespan,
                     busy=[busy[i] for i in range(cfg.p)],
                     load_stall=stall, timeline=timeline)


def mfu_from_sim(res: SimResult, model_flops: float, p: int, t: int,
                 peak_flops: float) -> float:
    """Observed-throughput MFU over the simulated step."""
    return model_flops / (res.makespan * p * t * peak_flops)


def ideal_makespan(cfg: SimConfig) -> float:
    """The paper's eq-2 idealization: (m + p - 1) * (Tf + Tb)."""
    return (cfg.m + cfg.p - 1) * (cfg.Tf + cfg.Tb)
