"""Discrete-event simulator of pipeline schedules (GPipe / 1F1B / BPipe,
plain and interleaved) — a handler set over ``plan.run``.

Validates the paper's closed-form estimates against explicit timelines and
quantifies what the paper *ignores* (its §4: "We also temporarily ignore
the overhead introduced by the BPipe technique"): eviction/load traffic
that fails to overlap shows up here as real makespan.

Model:
  * per-stage compute: Tf(b) forward, Tb(b) backward per microbatch; for
    interleaved kinds each of the v chunks does 1/v of the work, so a
    chunk's F costs Tf/v and its B costs Tb/v,
  * p2p boundary transfer between adjacent *virtual* stages: t_p2p
    (charged on every compiled dependency edge whose ``dep_hop`` is set —
    including the device p-1 -> device 0 wraparound between chunks),
  * residency moves (EVICT/LOAD, OFFLOAD/FETCH, plugin policies): priced
    by the transfer engine (``repro.transfer``) on explicit per-device
    channels — the shared evictor<->acceptor pair link for the swap
    (bytes / pair_bw * hops), the direction-split D2H/H2D host link for
    offload (bytes / d2h_bw resp. h2d_bw). Each channel is a serialized
    FIFO, so overlap (or the lack of it) falls out of channel-queue
    occupancy rather than per-op special cases. A move's compiled ISSUE
    half starts the transfer as soon as its dependency is ready — a
    restore is issued up to ``spec.depth`` chunk-level F+B slots ahead
    of the backward it feeds (depth 1 = the classic one-slot prefetch,
    whose ``(Tf+Tb)/(2v)`` stall threshold is golden-pinned in
    tests/test_plan.py) — and the backward stalls only if the transfer
    is still in flight when it starts,
  * recompute-mechanism policies have no channel: DROP is free
    bookkeeping, and RECOMPUTE occupies the stage's compute frontier for
    one chunk-level forward (Tf/v) — the FLOPs bill of recomputation.

Pricing handlers are derived from the policy registry's mechanism field,
so a newly registered policy's ops are priced without edits here.

The schedule itself — streams, dependency edges, device hops, partner
map — comes precompiled from ``plan.compile_plan``; this module only
prices instructions. Makespans across plain/interleaved/BPipe/residency
variants are directly comparable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import plan as P
from repro.core.schedule import B, F
from repro.memory import policy as respol
from repro.transfer import TransferEngine
from repro.transfer.channel import ChannelStats


@dataclasses.dataclass
class SimConfig:
    """Cost knobs plus the schedule variant to price.

    Preferred: ``SimConfig(spec=ScheduleSpec(...), Tf=..., Tb=...)``.
    Legacy: the (p, m, kind, v, cap) knobs construct the spec — kept as a
    deprecation shim; ``spec`` wins when both are given (it re-syncs the
    legacy fields so old readers of ``cfg.p``/``cfg.kind`` stay correct).
    """
    p: int = 0
    m: int = 0                  # microbatches
    Tf: float = 0.0             # forward time per microbatch per device
    Tb: float = 0.0             # backward time (typically 2*Tf)
    t_p2p: float = 0.0          # stage-boundary activation transfer
    evict_bytes: float = 0.0    # bytes per residency move (EVICT/OFFLOAD/..)
    pair_bw: float = float("inf")
    pair_hops: int = 1
    d2h_bw: float = float("inf")   # host link, device -> host (OFFLOAD)
    h2d_bw: float = float("inf")   # host link, host -> device (FETCH)
    t_vocab: float = 0.0        # vocab-parallel collective per boundary
                                # F/B (memory_model.vocab_collective_bytes
                                # / link bw; 0 at vocab_parallel=1)
    kind: str = "1f1b"
    v: int = 2                  # chunks per device (interleaved kinds only)
    cap: Optional[int] = None   # stash-cap override (balanced / residency)
    residency: str = "none"     # residency policy (plain kinds)
    depth: int = 1              # transfer-overlap depth (docs/transfer.md)
    spec: Optional[P.ScheduleSpec] = None

    def __post_init__(self):
        if self.spec is not None:
            self.p, self.m = self.spec.p, self.spec.m
            self.kind, self.cap = self.spec.kind, self.spec.cap
            self.residency = self.spec.residency
            self.depth = self.spec.depth
            if self.spec.interleaved:
                self.v = self.spec.v

    def to_spec(self) -> P.ScheduleSpec:
        """The schedule variant this config prices."""
        if self.spec is not None:
            return self.spec
        # residency goes into the constructor directly: building a
        # residency-less spec first would null a cap override (no active
        # policy -> no cap) before the replace could re-activate it
        return P.ScheduleSpec(self.kind, self.p, self.m, v=self.v,
                              cap=self.cap, residency=self.residency,
                              depth=self.depth)


@dataclasses.dataclass
class SimResult:
    makespan: float
    busy: List[float]           # per-stage compute-busy time
    load_stall: float           # total time backwards waited on restores
    timeline: Dict[int, List]   # (op, mb, chunk, sl, start, end) per stage
    move_time: float = 0.0      # summed residency-op time (link occupancy
                                # for swap/host moves, re-forward time for
                                # recompute) — the overhead exposure that
                                # breaks equal-makespan ties in the planner
    vocab_time: float = 0.0     # summed vocab-parallel collective time
                                # charged on boundary-stage F/B
    channels: Dict[tuple, ChannelStats] = dataclasses.field(
        default_factory=dict)   # per-channel occupancy (transfer engine)

    @property
    def bubble_fraction(self) -> float:
        total = self.makespan * len(self.busy)
        return 1.0 - sum(self.busy) / total

    @property
    def queue_peak(self) -> int:
        """Max in-flight transfers reached on any channel (0 when the
        schedule moves nothing) — bounded by ``spec.depth``."""
        return max((s.queue_peak for s in self.channels.values()),
                   default=0)


def _simulate(cfg: SimConfig, greedy: bool = True,
              observer=None) -> SimResult:
    spec = cfg.to_spec()
    schedule = P.compile_plan(spec)
    p, v = spec.p, spec.v
    # One full microbatch of F work per device is Tf regardless of v:
    # each chunk holds 1/v of the device's layers. Sequence slicing
    # divides the unit again — a slice is 1/seq_chunks of the tokens, so
    # sliced F/B cost Tf/(v*c), Tb/(v*c) on the compute frontier. (The
    # quadratic attention share of a slice actually shrinks sub-linearly;
    # the planner's cost model owns that refinement, the engine prices
    # the linear part.)
    c = spec.seq_chunks
    tf, tb = cfg.Tf / (v * c), cfg.Tb / (v * c)
    # Vocab-parallel collectives (spec.vocab_parallel > 1) ride the
    # boundary stages' compute frontier: every F and B of the first and
    # last *virtual* stage pays one all-reduce/gather of the (sliced)
    # boundary activation — cfg.t_vocab seconds, 1/c of it per slice.
    # The guard keeps the vp=1 hot path's arithmetic untouched.
    nv = p * v
    tvoc = cfg.t_vocab / c if cfg.t_vocab else 0.0
    t_move = (cfg.evict_bytes / cfg.pair_bw) * cfg.pair_hops \
        if cfg.evict_bytes else 0.0
    t_d2h = cfg.evict_bytes / cfg.d2h_bw if cfg.evict_bytes else 0.0
    t_h2d = cfg.evict_bytes / cfg.h2d_bw if cfg.evict_bytes else 0.0
    engine = TransferEngine(schedule, t_peer=t_move, t_d2h=t_d2h,
                            t_h2d=t_h2d, depth=spec.depth,
                            observer=observer)
    # Restores are issued up to ``depth`` chunk-level F+B slots ahead of
    # the backward they feed (issue-early): deeper overlap starts the
    # transfer earlier and rides the channel queue instead of the stage.
    window = spec.depth * (tf + tb)

    t_stage = {i: 0.0 for i in range(p)}    # stage compute frontier
    done: Dict[P.DepKey, float] = {}    # (op, stage, mb, chunk, sl) -> end
    busy = {i: 0.0 for i in range(p)}
    state = {"stall": 0.0, "last_b": 0.0, "move": 0.0, "vocab": 0.0}
    timeline: Dict[int, List] = {i: [] for i in range(p)}

    def finish(i, ins, start_t, end_t):
        timeline[i].append((ins.op, ins.mb, ins.chunk, ins.sl,
                            start_t, end_t))
        if observer is not None:
            # the observer sees the full schema (phase included); the
            # SimResult timeline keeps its pre-obs tuple shape untouched
            observer.emit(ins.op, i, ins.mb, ins.chunk, ins.sl, ins.phase,
                          start_t, end_t)

    def on_f(i, ins):
        if ins.dep is None:
            dep = 0.0
        else:
            dep = done.get(ins.dep)
            if dep is None:
                return P.BLOCKED
        hop = cfg.t_p2p if ins.dep_hop else 0.0
        start_t = max(t_stage[i], dep + hop)
        dt = tf
        if tvoc and (ins.vs == 0 or ins.vs == nv - 1):
            dt = tf + tvoc
            state["vocab"] += tvoc
        end_t = start_t + dt
        done[ins.done_key] = end_t
        busy[i] += dt
        t_stage[i] = end_t
        finish(i, ins, start_t, end_t)

    def on_b(i, ins):
        dep = done.get(ins.dep)
        if dep is None:
            return P.BLOCKED
        hop = cfg.t_p2p if ins.dep_hop else 0.0
        start_t = max(t_stage[i], dep + hop)
        for rop in _stall_ops:     # data-moving restores gate the backward
            le = done.get((rop, i, ins.mb, ins.chunk, ins.sl))
            if le is not None and le > start_t:
                state["stall"] += le - start_t
                start_t = le
        dt = tb
        if tvoc and (ins.vs == 0 or ins.vs == nv - 1):
            dt = tb + tvoc
            state["vocab"] += tvoc
        end_t = start_t + dt
        done[ins.done_key] = end_t
        state["last_b"] = max(state["last_b"], end_t)
        busy[i] += dt
        t_stage[i] = end_t
        finish(i, ins, start_t, end_t)

    def wait_span(i, ins):
        # WAIT halves are free in simulated time (completion is already
        # priced; the backward charges any residual stall), but they ARE
        # instructions — the observer sees a zero-duration barrier span
        # at the move's completion so sim and executor streams carry the
        # same instruction set. Never appended to the SimResult timeline.
        if observer is not None:
            t = done.get(ins.dep, 0.0)
            observer.emit(ins.op, i, ins.mb, ins.chunk, ins.sl, ins.phase,
                          t, t)

    def on_release(i, ins):
        # ISSUE: the copy starts when the unit's F finished and the
        # channel admits it; async — the stage frontier is untouched.
        # WAIT halves are free here: completion is already priced, and
        # the restore's dep edge consumes it.
        if ins.is_wait:
            return wait_span(i, ins)
        pol = respol.RELEASE_OPS[ins.op]
        ready = done[ins.dep]
        if pol.mechanism == "recompute":
            # freeing residuals is bookkeeping — no time, no link
            done[ins.done_key] = ready
            finish(i, ins, ready, ready)
            return None
        start_t, end_t = engine.issue(pol, i, ready, release=True, ins=ins)
        done[ins.done_key] = end_t
        state["move"] += end_t - start_t
        finish(i, ins, start_t, end_t)
        return None

    def on_restore(i, ins):
        # ISSUE: prefetched into the depth-sized window ahead of the
        # backward; the WAIT half is the completion barrier the backward
        # observes (charged there, as load-stall).
        if ins.is_wait:
            return wait_span(i, ins)
        pol = respol.RESTORE_OPS[ins.op]
        if pol.mechanism == "recompute":
            # re-run the chunk's forward ON the compute frontier: the
            # FLOPs bill of recomputation the paper's recompute arms pay
            start_t = max(t_stage[i], done[ins.dep])
            end_t = start_t + tf
            done[ins.done_key] = end_t
            state["move"] += tf
            busy[i] += tf
            t_stage[i] = end_t
            finish(i, ins, start_t, end_t)
            return None
        issue_t = max(0.0, t_stage[i] - window)
        ready = max(issue_t, done[ins.dep])
        start_t, end_t = engine.issue(pol, i, ready, release=False, ins=ins)
        done[ins.done_key] = end_t
        state["move"] += end_t - start_t
        finish(i, ins, start_t, end_t)
        return None

    # Pricing handlers by registered policy mechanism (via the transfer
    # engine): swap ops ride the pair link, host ops the per-device
    # direction-split host link, recompute ops the compute frontier. A
    # policy registered by a plugin is priced here with no simulator
    # edits.
    handlers = {F: on_f, B: on_b}
    for op in respol.RELEASE_OPS:
        handlers[op] = on_release
    for op in respol.RESTORE_OPS:
        handlers[op] = on_restore
    _stall_ops = tuple(op for op, pol in respol.RESTORE_OPS.items()
                       if pol.moves_data)

    P.run(schedule.streams, handlers, greedy=greedy, observer=observer,
          dep_gated=True)
    makespan = max(max(t_stage.values()), state["last_b"])
    return SimResult(makespan=makespan,
                     busy=[busy[i] for i in range(p)],
                     load_stall=state["stall"], timeline=timeline,
                     move_time=state["move"], vocab_time=state["vocab"],
                     channels=engine.stats())


# Public entry point. The dispatch loop itself lives in ``plan.run`` —
# this module contributes only the pricing handlers above. ``greedy``
# selects the engine order (True = dataflow drain, False = round-robin);
# for every channel with a single issuing stage the priced timeline is
# identical either way (the differential fuzz harness pins this).
simulate = _simulate


def mfu_from_sim(res: SimResult, model_flops: float, p: int, t: int,
                 peak_flops: float) -> float:
    """Observed-throughput MFU over the simulated step."""
    return model_flops / (res.makespan * p * t * peak_flops)


def ideal_makespan(cfg: SimConfig) -> float:
    """The paper's eq-2 idealization: (m + p - 1) * (Tf + Tb)."""
    return (cfg.m + cfg.p - 1) * (cfg.Tf + cfg.Tb)


def interleaved_ideal_makespan(cfg: SimConfig) -> float:
    """Megatron interleaved idealization: the pipeline ramp shrinks to
    (p - 1)/v flush units, so makespan ~= (m + (p - 1)/v)(Tf + Tb)."""
    return (cfg.m + (cfg.p - 1) / cfg.v) * (cfg.Tf + cfg.Tb)


def sliced_ideal_makespan(cfg: SimConfig) -> float:
    """Sequence-sliced idealization (SlimPipe direction): the fill/drain
    ramp is one slice per stage hop, so it shrinks c-fold and
    makespan ~= (m + (p - 1)/c)(Tf + Tb). At c=1 this is exactly the
    paper's eq-2 bound; for c > 1 slicing trades bubble for retained-KV
    memory — the quantity ``memory_model`` charges back."""
    c = cfg.to_spec().seq_chunks
    return (cfg.m + (cfg.p - 1) / c) * (cfg.Tf + cfg.Tb)
