"""Paper notation (Table 1) as a dataclass, so formulas read like the paper.

a: attention heads, b: micro batch size, h: hidden dim, l: layers,
s: sequence length, v: vocab, B: global batch, p: pipeline size,
t: tensor parallel size.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Notation:
    a: int   # attention heads
    b: int   # micro batch size
    h: int   # hidden dim
    l: int   # layers
    s: int   # sequence length
    v: int   # vocab size
    B: int   # global batch size
    p: int   # pipeline parallel size
    t: int   # tensor parallel size

    @property
    def num_micro(self) -> int:
        assert self.B % self.b == 0, (self.B, self.b)
        return self.B // self.b

    def replace(self, **kw) -> "Notation":
        return dataclasses.replace(self, **kw)


def from_model(cfg: ModelConfig, *, b=1, s=2048, B=128, p=8, t=4) -> Notation:
    return Notation(a=cfg.num_heads, b=b, h=cfg.d_model, l=cfg.num_layers,
                    s=s, v=cfg.vocab_size, B=B, p=p, t=t)


# Paper Table 2 rows.
GPT3_96B = Notation(a=104, b=1, h=9984, l=80, s=2048, v=51200, B=128, p=8, t=4)
LLAMA_65B = Notation(a=64, b=1, h=8192, l=80, s=2048, v=32000, B=128, p=8, t=4)

# Hardware constants. The paper ran A100s; our target is TPU v5e.
A100_PEAK_BF16 = 312e12
TPU_V5E_PEAK_BF16 = 197e12
TPU_V5E_HBM_BW = 819e9
TPU_V5E_ICI_BW = 50e9
TPU_V5E_HBM_BYTES = 16 * 1024**3
A100_HBM_BYTES = 80 * 1024**3
NVLINK_BW = 300e9  # effective per-direction A100 NVLink
PCIE_BW = 25e9     # effective per-direction PCIe gen4 x16 (host offload)
