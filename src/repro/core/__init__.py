"""The paper's primary contribution: BPipe memory-balanced pipeline
parallelism — schedules, eviction planning, analytical memory model,
the paper-§4 performance estimator, and a discrete-event pipeline simulator.
"""
from repro.core import bpipe, estimator, flops, memory_model, notation, schedule, simulator  # noqa: F401
