"""Compiled schedule plans: the one place a pipeline schedule is turned
from a name-plus-knobs into an executable artifact.

The paper's whole argument is a comparison across schedule variants, so a
variant must be a *value*, not a loose ``(kind, p, m, v, cap)`` tuple
re-threaded through every module. Following the plan-as-artifact designs
of Alpa (compile the parallel plan once, hand it to every consumer) and
Megatron-LM's schedule registry:

  * ``ScheduleSpec`` — the typed, validated, hashable identity of a
    schedule variant. Everything downstream (simulator, executor, memory
    model, planner, benchmarks) speaks specs.
  * ``compile_plan(spec) -> Schedule`` — compiled ONCE (lru-cached on the
    spec): per-stage instruction streams with each instruction's resolved
    upstream dependency edge and device hop, the evictor/acceptor partner
    map, per-stage stash bounds, eviction/load counts, and peak-stash
    accounting. Every residency move is split into ISSUE/WAIT halves —
    the issue-early/complete-lazy transfer contract (docs/transfer.md)
    the simulator prices on channels and the executor maps onto real
    async copies. Consumers stop re-deriving any of this per call.
  * ``run(streams, handlers)`` — the single generic ready-instruction
    dispatch loop (with deadlock detection). The discrete-event simulator,
    the executable runtime, and the stash accounting are all handler sets
    over this engine; none of them owns a scheduling loop anymore.

Adding a schedule kind is one declarative ``schedule.register(...)`` call
(stream builder + flags + cap formulas); it is then compilable, plannable,
simulable, and executable with no interpreter edits. See docs/api.md.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from repro.core import schedule as sched
from repro.core.schedule import B, EVICT, F, LOAD, Instr
# Importing the policy module via the package registers the built-in
# residency policies (none / bpipe_swap / host_offload /
# selective_recompute) before any spec validates against them.
from repro.memory import policy as respol

# Dependency edge: completion of (op, stage, mb, chunk, sl) upstream.
DepKey = Tuple[str, int, int, int, int]


# ---------------------------------------------------------------------------
# ScheduleSpec — the schedule variant as a value
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Identity of one pipeline-schedule variant.

    Fields:
      kind: registered schedule kind (``schedule.SCHEDULES``).
      p:    pipeline stages (devices).
      m:    microbatches per step. ``m=0`` leaves the spec *unbound* — a
            template the executor binds to the real batch at ``step()``
            (``with_m``); compiling requires a bound spec.
      v:    virtual chunks per device; normalized to 1 for plain kinds.
      cap:  local-stash bound override for balanced (BPipe-family) kinds
            and for active residency policies on plain kinds; normalized
            to None when it equals the default bound (and when nothing
            caps the stash), so two spellings of the same variant hash
            and compare equal.
      residency: where a stashed activation lives between its F and its
            B (``repro.memory.policy.POLICIES``). Balanced kinds embed
            the partner swap, so their residency normalizes to
            ``"bpipe_swap"``; unbalanced kinds accept ``"none"``,
            ``"host_offload"``, ``"selective_recompute"`` (or any
            registered policy whose mechanism is not the swap).
      depth: transfer-overlap depth (docs/transfer.md): how many
            residency moves may be in flight per channel, and how many
            chunk-level F+B slots ahead of its backward a restore is
            issued. ``depth=1`` is the classic serialized contract (one
            in-flight transient, one-slot prefetch — today's behavior,
            golden-pinned); deeper overlap hides slower links at the
            cost of ``depth-1`` extra in-flight units of device memory.
            Normalized to 1 when the residency policy moves no bytes
            over a channel (``none``, ``selective_recompute``).
      seq_chunks: sequence slices per microbatch (SlimPipe direction,
            docs/longcontext.md). ``seq_chunks=c > 1`` makes one slice
            the pipeline unit: forwards visit slices in causal order
            (slice i's attention reads the retained KV of slices < i),
            backwards run in reverse slice order, and activation stashes
            shrink to ~1/c of a microbatch plus the retained-KV prefix.
            Normalized to 1 for kinds without a sliced builder
            (``ScheduleKind.sliced`` — interleaved kinds cannot slice).
            ``seq_chunks=1`` is bit-identical to the unsliced engine.
      vocab_parallel: vocabulary-parallel degree (docs/memory.md "Vocab
            accounting"; arxiv 2411.05288 direction). ``vocab_parallel=
            vp > 1`` scatters the embedding table over the first vp
            stages and the LM head + fp32 logits over the last vp
            stages, trading the boundary-stage vocab memory spike for
            per-microbatch all-reduce/gather traffic on the boundary
            stages' F/B. Like ``depth``, a *pricing* dimension: the
            compiled streams and peak-stash accounting are those of the
            vp=1 structural twin (re-bound, never re-compiled); only
            the memory model's ``vocab_bytes`` split and the
            simulator's boundary-collective charge read it. Must
            satisfy ``1 <= vp <= p``; normalized to 1 when p == 1
            (nothing to scatter over). ``vocab_parallel=1`` is
            bit-identical to the unscattered engine.

    Specs are frozen and hashable — they key the compile cache and can be
    used as dict keys / set members anywhere a "schedule variant" is
    meant.
    """
    kind: str
    p: int
    m: int = 0
    v: int = 1
    cap: Optional[int] = None
    residency: str = "none"
    depth: int = 1
    seq_chunks: int = 1
    vocab_parallel: int = 1

    def __post_init__(self):
        entry = sched.SCHEDULES.get(self.kind)
        if entry is None:
            raise ValueError(
                f"unknown schedule kind {self.kind!r}; "
                f"registered: {sorted(sched.SCHEDULES)}")
        pol = respol.POLICIES.get(self.residency)
        if pol is None:
            raise ValueError(
                f"unknown residency policy {self.residency!r}; "
                f"registered: {sorted(respol.POLICIES)}")
        if entry.balanced:
            # balanced kinds ARE the swap policy (their builders emit
            # EVICT/LOAD); normalize so the spec says so, and reject a
            # contradictory residency rather than silently dropping it
            if self.residency not in ("none", respol.BPIPE_SWAP.name):
                raise ValueError(
                    f"{self.kind} embeds the partner swap; "
                    f"residency={self.residency!r} conflicts — use the "
                    f"unbalanced base kind for other policies")
            object.__setattr__(self, "residency", respol.BPIPE_SWAP.name)
            pol = respol.BPIPE_SWAP
        elif pol.swap:
            raise ValueError(
                f"residency {self.residency!r} is the balanced kinds' "
                f"built-in mechanism; use the bpipe twin of {self.kind!r}")
        if self.p < 1:
            raise ValueError(f"p must be >= 1, got {self.p}")
        if self.m < 0:
            raise ValueError(f"m must be >= 0, got {self.m}")
        if entry.interleaved:
            if self.v < 2:
                raise ValueError(
                    f"{self.kind} needs v >= 2 chunks, got v={self.v}")
            if self.m and self.m % self.p:
                raise ValueError(
                    f"{self.kind} needs m % p == 0, got m={self.m} p={self.p}")
        else:
            # plain kinds have exactly one chunk; normalize so the spec's
            # identity doesn't depend on a meaningless v knob
            object.__setattr__(self, "v", 1)
        if self.seq_chunks < 1:
            raise ValueError(
                f"seq_chunks must be >= 1, got {self.seq_chunks}")
        if self.seq_chunks != 1 and not entry.sliced:
            # kinds without a sliced builder (interleaved kinds — the
            # sliced ramp deadlocks against chunk-major unit order — and
            # plugin kinds that never opted in) run unsliced
            object.__setattr__(self, "seq_chunks", 1)
        # caps count sliced units, and the default bound widens by the
        # extra seq_chunks - 1 warmup slices (schedule.schedule_cap)
        cap_extra = self.seq_chunks - 1
        if entry.balanced:
            if self.cap is not None:
                if self.cap < 2:
                    raise ValueError(
                        f"cap must be >= 2 (one live forward + the "
                        f"in-flight LOAD transient), got {self.cap}")
                if self.cap == entry.default_cap(self.p, self.v) + cap_extra:
                    object.__setattr__(self, "cap", None)
        elif pol.active:
            if self.cap is not None:
                if self.cap < 2:
                    raise ValueError(
                        f"cap must be >= 2 (one live forward + the "
                        f"in-flight restore transient), got {self.cap}")
                if self.cap == pol.default_cap(self.p, self.v) + cap_extra:
                    object.__setattr__(self, "cap", None)
        else:
            object.__setattr__(self, "cap", None)
        if self.vocab_parallel < 1:
            raise ValueError(
                f"vocab_parallel must be >= 1, got {self.vocab_parallel}")
        if self.p == 1:
            # a single stage holds everything; nothing to scatter over
            object.__setattr__(self, "vocab_parallel", 1)
        elif self.vocab_parallel > self.p:
            raise ValueError(
                f"vocab_parallel={self.vocab_parallel} > p={self.p}: "
                f"vocab shards scatter over pipeline stages")
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if not (entry.balanced or pol.moves_data):
            # depth is a *transfer* dimension: when the policy moves no
            # bytes over a channel (none, selective_recompute) there is
            # nothing to overlap — normalize so the knob is not a
            # spurious identity dimension
            object.__setattr__(self, "depth", 1)

    # -- derived identity ------------------------------------------------
    @property
    def entry(self) -> "sched.ScheduleKind":
        return sched.SCHEDULES[self.kind]

    @property
    def interleaved(self) -> bool:
        return self.entry.interleaved

    @property
    def balanced(self) -> bool:
        return self.entry.balanced

    @property
    def policy(self) -> "respol.ResidencyPolicy":
        """The residency policy governing where stashes live."""
        return respol.POLICIES[self.residency]

    @property
    def n_virtual(self) -> int:
        return self.p * self.v

    @property
    def resolved_cap(self) -> Optional[int]:
        """The effective per-device stash bound (None = unbounded). Caps
        count sliced units; defaults widen by seq_chunks - 1 (the extra
        sliced warmup ramp)."""
        extra = self.seq_chunks - 1
        if self.balanced:
            return self.cap if self.cap is not None \
                else self.entry.default_cap(self.p, self.v) + extra
        pol = self.policy
        if pol.active:
            return self.cap if self.cap is not None \
                else pol.default_cap(self.p, self.v) + extra
        return None

    @property
    def bound(self) -> bool:
        return self.m > 0

    def with_m(self, m: int) -> "ScheduleSpec":
        """Bind (or re-bind) the microbatch count."""
        return dataclasses.replace(self, m=m)

    # -- presentation / serialization -------------------------------------
    def label(self) -> str:
        bits = [self.kind, f"p={self.p}", f"m={self.m}"]
        if self.interleaved:
            bits.append(f"v={self.v}")
        if not self.balanced and self.policy.active:
            bits.append(f"res={self.residency}")
        if self.balanced or self.policy.active:
            bits.append(f"cap={self.cap if self.cap is not None else 'def'}")
        if self.depth != 1:
            bits.append(f"depth={self.depth}")
        if self.seq_chunks != 1:
            bits.append(f"c={self.seq_chunks}")
        if self.vocab_parallel != 1:
            bits.append(f"vp={self.vocab_parallel}")
        return " ".join(bits)

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "p": self.p, "m": self.m,
                "v": self.v, "cap": self.cap, "residency": self.residency,
                "depth": self.depth, "seq_chunks": self.seq_chunks,
                "vocab_parallel": self.vocab_parallel}

    #: Exactly the keys ``to_dict`` emits — ``from_dict`` rejects anything
    #: else so a typo'd or stale spec JSON fails loudly instead of
    #: silently dropping a dimension.
    DICT_KEYS = frozenset(("kind", "p", "m", "v", "cap", "residency",
                           "depth", "seq_chunks", "vocab_parallel"))

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScheduleSpec":
        unknown = sorted(set(d) - cls.DICT_KEYS)
        if unknown:
            raise ValueError(
                f"unknown ScheduleSpec keys {unknown}; "
                f"allowed: {sorted(cls.DICT_KEYS)}")
        return cls(kind=d["kind"], p=int(d["p"]), m=int(d.get("m", 0)),
                   v=int(d.get("v", 1)),
                   cap=None if d.get("cap") is None else int(d["cap"]),
                   residency=str(d.get("residency", "none")),
                   depth=int(d.get("depth", 1)),
                   seq_chunks=int(d.get("seq_chunks", 1)),
                   vocab_parallel=int(d.get("vocab_parallel", 1)))


# ---------------------------------------------------------------------------
# Compiled instructions
# ---------------------------------------------------------------------------
#: Phases of a residency move under the issue-early/complete-lazy
#: contract (docs/transfer.md): the ISSUE half starts the transfer as
#: soon as its dependency is ready, the WAIT half blocks the dependent
#: compute until the transfer really completed. Compute ops (F/B) carry
#: the empty phase.
ISSUE, WAIT = "issue", "wait"


@dataclasses.dataclass(frozen=True)
class PlannedInstr:
    """One schedule instruction with its dispatch context resolved at
    compile time: the virtual stage it runs on, the upstream completion
    it waits for (``dep``), and whether that dependency crosses a device
    boundary (``dep_hop`` — the p2p transfer the simulator charges and a
    multi-host runtime would device_put).

    Residency moves are compiled into two halves (``phase``): the ISSUE
    half (dep: what the move waits for — the unit's own F for a
    release, the release's completion for a restore) and the WAIT half
    (dep: the move's own completion), placed where the completion is
    consumed. Both halves share the op name and publish/consume the
    same canonical ``done_key``."""
    op: str
    stage: int
    mb: int
    chunk: int
    vs: int                        # virtual stage = chunk * p + stage
    dep: Optional[DepKey] = None   # (op, stage, mb, chunk, sl) upstream
    dep_hop: bool = False
    phase: str = ""                # "", ISSUE or WAIT
    sl: int = 0                    # sequence slice (seq_chunks > 1 only)

    @property
    def key(self) -> Tuple[int, int, int, int]:
        return (self.stage, self.mb, self.chunk, self.sl)

    @property
    def done_key(self) -> DepKey:
        """The completion record this instruction publishes."""
        return (self.op, self.stage, self.mb, self.chunk, self.sl)

    @property
    def is_wait(self) -> bool:
        return self.phase == WAIT

    def as_instr(self) -> Instr:
        return Instr(self.op, self.mb, self.chunk, self.sl)

    def __repr__(self):
        c = f".c{self.chunk}" if self.chunk else ""
        s = f".s{self.sl}" if self.sl else ""
        w = "+w" if self.phase == WAIT else ""
        return f"{self.op}{self.mb}{c}{s}{w}@{self.stage}"


def _plan_stream(spec: ScheduleSpec, stage: int,
                 raw: Sequence[Instr]) -> Tuple[PlannedInstr, ...]:
    """Resolve each raw instruction's dependency edge and device hop.

    Every dependency shares the instruction's sequence slice: a sliced
    F(mb, sl) consumes the previous virtual stage's F of the SAME slice,
    and the causal order across slices (slice i's attention reads the
    retained KV of slices < i on the same stage) is already program
    order within the stage's stream, so it needs no extra edge."""
    p, nv = spec.p, spec.n_virtual
    out: List[PlannedInstr] = []
    for ins in raw:
        vs = sched.virtual_stage(stage, ins.chunk, p)
        dep: Optional[DepKey] = None
        hop = False
        if ins.op == F:
            if vs > 0:
                pi, pc = (vs - 1) % p, (vs - 1) // p
                dep = (F, pi, ins.mb, pc, ins.sl)
                hop = pi != stage
        elif ins.op == B:
            if vs == nv - 1:
                dep = (F, stage, ins.mb, ins.chunk, ins.sl)  # own forward
            else:
                ni, nc = (vs + 1) % p, (vs + 1) // p
                dep = (B, ni, ins.mb, nc, ins.sl)
                hop = ni != stage
        elif ins.op in respol.RELEASE_OPS:
            # any residency release (EVICT/OFFLOAD/DROP/...) waits on the
            # unit's own forward
            dep = (F, stage, ins.mb, ins.chunk, ins.sl)
        elif ins.op in respol.RESTORE_OPS:
            # any restore (LOAD/FETCH/RECOMPUTE/...) waits on its release
            dep = (respol.RESTORE_OPS[ins.op].release_op,
                   stage, ins.mb, ins.chunk, ins.sl)
        else:
            raise ValueError(f"unknown op {ins.op!r}")
        out.append(PlannedInstr(ins.op, stage, ins.mb, ins.chunk, vs,
                                dep, hop, sl=ins.sl))
    return tuple(out)


def _split_stream(stream: Sequence[PlannedInstr]) -> Tuple[PlannedInstr, ...]:
    """Split every residency move into its ISSUE/WAIT halves.

    Placement is the issue-early/complete-lazy contract:
      * a release's ISSUE sits where the move sat (right after the
        covering forward — the earliest its data exists); its WAIT sits
        immediately before the matching restore's ISSUE, the first point
        its completion is consumed;
      * a restore's ISSUE sits where the move sat and its WAIT directly
        after — i.e. just before the backward that needs the data.

    Positions of compute ops (and of the canonical move events) are
    unchanged, so the depth-1 engine prices exactly the serialized
    timeline this refactor replaced (golden-pinned), and the stash/spill
    accounting runs on the unsplit stream and stays bit-identical.
    """
    out: List[PlannedInstr] = []
    pending: Dict[Tuple[str, int, int, int], PlannedInstr] = {}
    for ins in stream:
        if ins.op in respol.RELEASE_OPS:
            out.append(dataclasses.replace(ins, phase=ISSUE))
            pending[(ins.op, ins.mb, ins.chunk, ins.sl)] = dataclasses.replace(
                ins, phase=WAIT, dep=ins.done_key, dep_hop=False)
        elif ins.op in respol.RESTORE_OPS:
            rel = respol.RESTORE_OPS[ins.op].release_op
            rel_wait = pending.pop((rel, ins.mb, ins.chunk, ins.sl), None)
            if rel_wait is not None:
                out.append(rel_wait)
            out.append(dataclasses.replace(ins, phase=ISSUE))
            out.append(dataclasses.replace(ins, phase=WAIT,
                                           dep=ins.done_key, dep_hop=False))
        else:
            out.append(ins)
    # a release with no restore cannot occur in a well-formed stream, but
    # tolerate it (its wait becomes a trailing barrier) rather than drop
    out.extend(pending.values())
    return tuple(out)


# ---------------------------------------------------------------------------
# The compiled artifact
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Schedule:
    """Everything a schedule consumer needs, computed once per spec.

    ``streams`` carry resolved deps/hops, with every residency move
    split into its ISSUE/WAIT halves (``PlannedInstr.phase`` — the
    transfer-engine IR, docs/transfer.md); ``partner`` is the BPipe
    evictor<->acceptor map (empty for unbalanced kinds); ``cap`` is the
    resolved uniform bound (None = unbounded); ``bounds`` the per-stage
    live-store assertion bound the executor enforces (the schedule's own
    per-stage peak under a custom cap — a tighter evictor cap
    legitimately raises the acceptor's peak above the uniform number);
    ``peak_stash`` the per-stage peak unit count (local + accepted
    foreign) that feeds the memory model and planner feasibility;
    ``peak_spilled`` the per-stage peak count of units released off the
    device store by a non-swap residency policy (host-resident for
    offload, residual-freed for recompute — byte-weighted per policy by
    the memory model); ``num_evictions``/``num_loads`` the per-stage
    release/restore op counts (EVICT/LOAD for the swap, OFFLOAD/FETCH,
    DROP/RECOMPUTE, ...) that feed traffic accounting.
    """
    spec: ScheduleSpec
    streams: Mapping[int, Tuple[PlannedInstr, ...]]
    partner: Mapping[int, int]
    cap: Optional[int]
    bounds: Mapping[int, Optional[int]]
    peak_stash: Mapping[int, int]
    num_evictions: Mapping[int, int]
    num_loads: Mapping[int, int]
    peak_spilled: Mapping[int, int] = dataclasses.field(default_factory=dict)

    @property
    def p(self) -> int:
        return self.spec.p

    @property
    def n_virtual(self) -> int:
        return self.spec.n_virtual

    @property
    def size(self) -> int:
        return sum(len(s) for s in self.streams.values())

    @property
    def moves(self) -> int:
        """Total EVICT + LOAD instructions over one step."""
        return (sum(self.num_evictions.values())
                + sum(self.num_loads.values()))

    def instr_streams(self) -> Dict[int, List[Instr]]:
        """The raw-``Instr`` view (the pre-compile IR, for legacy callers
        and stream-shape tests): WAIT halves collapse away and each move
        appears once, at its ISSUE position — exactly the pre-split
        stream shape (golden-pinned)."""
        return {i: [pi.as_instr() for pi in s if not pi.is_wait]
                for i, s in self.streams.items()}


def partner_map(p: int) -> Dict[int, int]:
    """BPipe evictor<->acceptor pairing as a symmetric map."""
    out: Dict[int, int] = {}
    for a, b in sched.bpipe_pairs(p):
        out[a] = b
        out[b] = a
    return out


#: Bounded LRU over compiled plans. A dict (insertion-ordered) rather
#: than ``functools.lru_cache`` so the planner can read hit/miss/bind
#: counters (``compile_cache_stats`` / ``launch.plan --verbose``) and so
#: depth re-binds share one structural compilation (see below).
_COMPILE_CACHE: Dict[ScheduleSpec, Schedule] = {}
_COMPILE_CACHE_MAX = 256
_COMPILE_STATS = {"hits": 0, "misses": 0, "evictions": 0, "binds": 0}


def compile_plan(spec: ScheduleSpec) -> Schedule:
    """Compile ``spec`` into a ``Schedule``. Cached on the spec (bounded
    LRU) — the planner's feasibility pass, the simulator, and the
    executor all share one compilation per variant.

    ``depth`` and ``vocab_parallel`` are *pricing* dimensions: they
    change what the simulator charges (and what the executor keeps in
    flight / how vocab shards lay out), never the compiled streams or
    peak-stash accounting. Specs that differ only in those knobs
    therefore share one structural compilation — the depth-1/vp-1
    artifact is compiled once and re-bound (``dataclasses.replace`` of
    the spec field) per knob setting, so a planner depth or
    vocab-parallel ladder costs one compile."""
    cached = _COMPILE_CACHE.get(spec)
    if cached is not None:
        _COMPILE_STATS["hits"] += 1
        # move-to-back = most recently used (dicts iterate in insertion
        # order, so the front is the eviction victim)
        _COMPILE_CACHE.pop(spec)
        _COMPILE_CACHE[spec] = cached
        return cached
    _COMPILE_STATS["misses"] += 1
    if spec.depth != 1 or spec.vocab_parallel != 1:
        base = compile_plan(dataclasses.replace(spec, depth=1,
                                                vocab_parallel=1))
        _COMPILE_STATS["binds"] += 1
        sch = dataclasses.replace(base, spec=spec)
    else:
        sch = _compile(spec)
    _COMPILE_CACHE[spec] = sch
    while len(_COMPILE_CACHE) > _COMPILE_CACHE_MAX:
        _COMPILE_CACHE.pop(next(iter(_COMPILE_CACHE)))
        _COMPILE_STATS["evictions"] += 1
    return sch


def _compile_cache_clear() -> None:
    _COMPILE_CACHE.clear()


compile_plan.cache_clear = _compile_cache_clear


def compile_cache_stats(reset: bool = False) -> Dict[str, int]:
    """Compile-cache counters: ``hits``/``misses`` (cache lookups),
    ``binds`` (misses served by re-binding a cached depth-1 structural
    template instead of compiling), ``evictions``, and the current
    ``size``/``maxsize``. ``reset=True`` zeroes the counters after
    reading (the cache itself is untouched)."""
    out = dict(_COMPILE_STATS, size=len(_COMPILE_CACHE),
               maxsize=_COMPILE_CACHE_MAX)
    if reset:
        for k in _COMPILE_STATS:
            _COMPILE_STATS[k] = 0
    return out


#: Peak accounting saturates in m: every registered kind that opts in
#: (``ScheduleKind.peak_saturates``) reaches its steady-state 1F1B
#: cadence within the warmup ramp, after which per-stage peak stash /
#: spill counts and load-positivity are m-independent. 4*p*seq_chunks is
#: comfortably past every builder's warmup (max (v+1)p-ish) and is
#: divisible by p, so it is a valid interleaved m. Verified by a grid
#: property test (tests/test_planner_bnb.py).
PEAK_SATURATION_FACTOR = 4


def peak_template_spec(spec: ScheduleSpec) -> ScheduleSpec:
    """The cheapest spec with identical per-stage peak accounting
    (``peak_stash``/``peak_spilled``/``bounds`` and load-positivity) —
    ``spec`` itself unless its kind saturates and m is past the
    saturation point, in which case m binds down to the saturation
    template. Feasibility-style consumers (``memory_model``) compile the
    template instead of the full stream; consumers that need the actual
    instruction streams or move *counts* must compile ``spec``."""
    entry = spec.entry
    if not entry.peak_saturates or not spec.bound:
        return spec
    msat = PEAK_SATURATION_FACTOR * spec.p * spec.seq_chunks
    if spec.m <= msat:
        return spec
    return dataclasses.replace(spec, m=msat)


def _compile(spec: ScheduleSpec) -> Schedule:
    if not spec.bound:
        raise ValueError(f"cannot compile unbound spec (m=0): {spec}")
    p = spec.p
    entry = spec.entry
    pol = spec.policy
    cap = spec.resolved_cap

    def raw(i: int) -> sched.Stream:
        base = entry.stream(p, spec.m, i, spec.v, spec.cap, spec.seq_chunks)
        if entry.balanced or not pol.active:
            # balanced builders embed their own spill (EVICT/LOAD)
            return base
        return pol.rewrite(base, cap)

    unsplit = {i: _plan_stream(spec, i, raw(i)) for i in range(p)}
    partner = partner_map(p) if spec.balanced else {}
    # Stash/spill accounting runs on the UNSPLIT streams: the split only
    # makes completion explicit, it does not move any residency event,
    # and accounting on the pre-split order keeps the round-robin merge
    # (and with it every golden-pinned peak) bit-identical.
    traces, spill_traces, counts = _account(unsplit, p, partner)
    streams = {i: _split_stream(unsplit[i]) for i in range(p)}
    peaks = {i: (max(t) if t else 0) for i, t in traces.items()}
    spilled = {i: (max(t) if t else 0) for i, t in spill_traces.items()}
    releases = {i: sum(1 for x in unsplit[i] if x.op in respol.RELEASE_OPS)
                for i in range(p)}
    restores = {i: sum(1 for x in unsplit[i] if x.op in respol.RESTORE_OPS)
                for i in range(p)}
    if cap is None:
        bounds: Dict[int, Optional[int]] = {i: None for i in range(p)}
    elif spec.cap is not None:
        bounds = dict(peaks)
    else:
        bounds = {i: cap for i in range(p)}
    return Schedule(spec=spec, streams=streams, partner=partner, cap=cap,
                    bounds=bounds, peak_stash=peaks,
                    num_evictions=releases, num_loads=restores,
                    peak_spilled=spilled)


def num_moves(spec: ScheduleSpec) -> int:
    """Total release + restore instructions one step of ``spec``
    performs (EVICT+LOAD, OFFLOAD+FETCH, DROP+RECOMPUTE, ...) — the
    count the planner charges bandwidth (or recompute FLOPs) with.
    Covers every balanced kind, residency policy and cap override (the
    counts come from the stream actually built, not a closed form); 0
    when nothing manages residency."""
    if not spec.balanced and not spec.policy.active:
        return 0
    return compile_plan(spec).moves


# ---------------------------------------------------------------------------
# The dispatch engine
# ---------------------------------------------------------------------------
class ScheduleDeadlock(RuntimeError):
    """No stage can make progress: a dependency cycle or a handler that
    blocks forever. Carries the per-stage program counters for debugging."""

    def __init__(self, idx: Mapping[int, int],
                 streams: Mapping[int, Sequence[Any]]):
        self.idx = dict(idx)
        stuck = {i: repr(streams[i][j]) for i, j in idx.items()
                 if j < len(streams[i])}
        super().__init__(f"schedule deadlock; next instruction per stage: "
                         f"{stuck}")


#: Sentinel a handler returns when its instruction's inputs are not ready
#: yet; the engine moves on to the next stage and retries later.
BLOCKED = object()

Handler = Callable[[int, Any], Any]


def run(streams: Mapping[int, Sequence[Any]],
        handlers: Mapping[str, Handler], *, greedy: bool = True,
        observer: Optional[Any] = None, dep_gated: bool = False) -> int:
    """The ready-instruction dispatch loop — the ONLY scheduling loop in
    the codebase. Simulator, executor, and stash accounting are handler
    sets over it.

    Each stage's stream is consumed in order; ``handlers[op](stage, ins)``
    executes one instruction or returns ``BLOCKED`` to signal that an
    upstream input has not been produced yet. ``greedy=True`` drains each
    stage as far as it can go per round (dataflow consumers: simulator,
    executor); ``greedy=False`` takes at most one instruction per stage
    per round — the deterministic round-robin merge the stash accounting
    counts over. A full round with no progress raises
    ``ScheduleDeadlock``. Returns the number of instructions dispatched.

    ``dep_gated=True`` selects the event-driven engine for compiled
    ``PlannedInstr`` streams whose handlers block exactly when
    ``ins.dep`` has not retired (the simulator and the executor): stages
    park on their head instruction's unretired dep and are re-queued by
    the retirement that satisfies it, instead of the engine re-scanning
    every stream every round. Dispatch order is bit-identical to the
    scan loop for both greedy and round-robin modes (property-pinned in
    tests). The default scan path remains for handler sets that do not
    follow the dep discipline — the stash accounting's blind round-robin
    counting merge, and raw ``Instr`` streams with no dep edges.

    ``observer`` (the ``repro.obs.events.Observer`` contract, duck-typed)
    gets a ``dispatch(stage, ins)`` callback for every instruction the
    loop retires, in engine order — the one seam every event stream
    (simulator timelines, executor traces, dispatch-order audits) hangs
    off. ``None`` (the default) is zero-cost: the loop body is exactly
    the pre-instrumentation code path.
    """
    if dep_gated:
        return _run_events(streams, handlers, greedy=greedy,
                           observer=observer)
    stages = sorted(streams)
    idx = {i: 0 for i in stages}
    remaining = sum(len(streams[i]) for i in stages)
    done = 0
    while remaining:
        progressed = False
        for i in stages:
            stream = streams[i]
            while idx[i] < len(stream):
                ins = stream[idx[i]]
                if handlers[ins.op](i, ins) is BLOCKED:
                    break
                idx[i] += 1
                remaining -= 1
                done += 1
                progressed = True
                if observer is not None:
                    observer.dispatch(i, ins)
                if not greedy:
                    break
        if not progressed:
            raise ScheduleDeadlock(idx, streams)
    return done


def _run_events(streams: Mapping[int, Sequence[Any]],
                handlers: Mapping[str, Handler], *, greedy: bool = True,
                observer: Optional[Any] = None) -> int:
    """Event-driven dispatch over dep-resolved streams (``run`` with
    ``dep_gated=True``).

    A stage whose head instruction's ``dep`` has not retired parks in
    ``waiting`` under that dep key; the dispatch that publishes the key
    re-queues every parked waiter. Two min-heaps replay the scan loop's
    visit order exactly: ``cur`` holds the stages still to visit this
    sweep (= one ``for i in stages`` round of the scan loop), ``nxt``
    the stages runnable next sweep. A waiter ``j`` woken while the
    cursor is at stage ``i`` goes to ``cur`` iff ``j > i`` — in the
    scan loop, exactly those stages would still be visited in the same
    round — else to ``nxt``. Both heaps empty with instructions
    remaining (or a full sweep of handler-level ``BLOCKED`` refusals,
    which the dep discipline says cannot happen) is the same deadlock
    the scan loop diagnoses.
    """
    idx = {i: 0 for i in streams}
    remaining = sum(len(s) for s in streams.values())
    done = 0
    retired: set = set()
    waiting: Dict[Any, List[int]] = {}
    cur = [i for i in streams if streams[i]]
    heapq.heapify(cur)
    nxt: List[int] = []
    push, pop = heapq.heappush, heapq.heappop
    while remaining:
        progressed = False
        while cur:
            i = pop(cur)
            stream = streams[i]
            n = len(stream)
            while idx[i] < n:
                ins = stream[idx[i]]
                dep = ins.dep
                if dep is not None and dep not in retired:
                    waiting.setdefault(dep, []).append(i)
                    break
                if handlers[ins.op](i, ins) is BLOCKED:
                    # a handler refusing a dep-retired instruction is
                    # outside the dep_gated contract; retry next sweep
                    # (a whole sweep of refusals raises below, exactly
                    # like a no-progress scan round)
                    push(nxt, i)
                    break
                idx[i] += 1
                remaining -= 1
                done += 1
                progressed = True
                retired.add(ins.done_key)
                for j in waiting.pop(ins.done_key, ()):
                    push(cur if j > i else nxt, j)
                if observer is not None:
                    observer.dispatch(i, ins)
                if not greedy:
                    if idx[i] < n:
                        dep = stream[idx[i]].dep
                        if dep is None or dep in retired:
                            push(nxt, i)
                        else:
                            waiting.setdefault(dep, []).append(i)
                    break
        if remaining and (not progressed or not nxt):
            raise ScheduleDeadlock(idx, streams)
        cur, nxt = nxt, cur
    return done


def _account(streams: Mapping[int, Sequence[Any]], p: int,
             partner: Optional[Mapping[int, int]] = None,
             ) -> Tuple[Dict[int, List[int]], Dict[int, List[int]],
                        Dict[int, int]]:
    """Replay ``streams`` through the engine with counting handlers for
    the full registered op set.

    Returns ``(traces, spill_traces, counts)``: per-stage traces of
    device-resident stashed-unit counts after each event (including
    foreign stashes accepted from the paired evictor), per-stage traces
    of units spilled OFF the device store by a non-swap policy
    (host-resident / residual-freed), and the final device counts (all
    zero for a well-formed schedule). Works on raw ``Instr`` and
    compiled ``PlannedInstr`` streams alike — the handlers read ``op``
    plus (when present) the ISSUE/WAIT ``phase``: a move counts once, at
    its ISSUE half; WAIT halves are completion barriers, not events.
    """
    partner = partner_map(p) if partner is None else partner
    counts = {i: 0 for i in range(p)}
    spilled = {i: 0 for i in range(p)}
    traces: Dict[int, List[int]] = {i: [] for i in range(p)}
    spill_traces: Dict[int, List[int]] = {i: [] for i in range(p)}

    def bump(i: int, delta: int) -> None:
        counts[i] += delta
        traces[i].append(counts[i])

    def on_f(i, ins):
        bump(i, +1)

    def on_b(i, ins):
        bump(i, -1)

    def on_release(i, ins):
        if getattr(ins, "phase", "") == WAIT:
            return None
        counts[i] -= 1
        if respol.RELEASE_OPS[ins.op].swap:
            if i not in partner:
                # the unpaired middle stage of an odd-p bpipe ring: a cap
                # tight enough to make it spill has nowhere to swap to
                raise ValueError(
                    f"cap forces stage {i} to evict but it has no swap "
                    f"partner (odd p): unbalanceable")
            counts[partner[i]] += 1
            traces[partner[i]].append(counts[partner[i]])
        else:
            spilled[i] += 1
            spill_traces[i].append(spilled[i])
        traces[i].append(counts[i])

    def on_restore(i, ins):
        if getattr(ins, "phase", "") == WAIT:
            return None
        counts[i] += 1
        if respol.RESTORE_OPS[ins.op].swap:
            counts[partner[i]] -= 1
            traces[partner[i]].append(counts[partner[i]])
        else:
            spilled[i] -= 1
            spill_traces[i].append(spilled[i])
        traces[i].append(counts[i])

    handlers: Dict[str, Handler] = {F: on_f, B: on_b}
    for op in respol.RELEASE_OPS:
        handlers[op] = on_release
    for op in respol.RESTORE_OPS:
        handlers[op] = on_restore
    run(streams, handlers, greedy=False)
    return traces, spill_traces, counts


def stash_accounting(streams: Mapping[int, Sequence[Any]], p: int,
                     partner: Optional[Mapping[int, int]] = None,
                     ) -> Tuple[Dict[int, List[int]], Dict[int, int]]:
    """Device-resident stash accounting (the legacy two-tuple view of
    ``_account`` — spill traces are the compiled ``Schedule``'s
    ``peak_spilled`` business)."""
    traces, _, counts = _account(streams, p, partner)
    return traces, counts
