"""FLOPs accounting (paper eq. 1 + general per-architecture counts).

Paper eq. 1 (matmul-only FLOPs of one fwd+bwd pass over micro batch b):
    F = 72 b s l h^2 (1 + s/6h + v/16lh)
The paper shows (§3.1) the same formula covers LLaMA because its three
FFN matmuls to 8/3 h cost 16 b s h^2, identical to GPT-3's 4h FFN.
"""
from __future__ import annotations

from repro.configs.base import ATTN, LOCAL, MLSTM, RGLRU, SLSTM, ModelConfig
from repro.core.notation import Notation


def paper_flops(n: Notation) -> float:
    """Eq. 1: fwd+bwd FLOPs for micro batch b (factor 72 = 24 fwd x 3)."""
    return 72.0 * n.b * n.s * n.l * n.h**2 * (1 + n.s / (6 * n.h) + n.v / (16 * n.l * n.h))


def paper_flops_fwd(n: Notation) -> float:
    """Forward-only share (1/3 of eq. 1 under the bwd = 2x fwd convention)."""
    return paper_flops(n) / 3.0


def stage_flops(n: Notation) -> float:
    """FLOPs of one pipeline stage (l/p layers; the vocab term is charged
    to the last stage in reality — the paper's F_stage uses the uniform
    share, which we mirror)."""
    return paper_flops(n) / n.p


# ---------------------------------------------------------------------------
# General per-architecture matmul FLOPs (for the assigned archs / roofline).
# ---------------------------------------------------------------------------
def layer_flops_fwd(cfg: ModelConfig, kind: str, b: int, s: int) -> float:
    """Forward matmul FLOPs of one layer (global batch slice b, seq s)."""
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    f = 0.0
    if kind in (ATTN, LOCAL):
        f += 2 * b * s * d * hd * (nq + 2 * nkv)          # qkv proj
        f += 2 * b * s * nq * hd * d                      # out proj
        ctx = min(s, cfg.window_size) if (kind == LOCAL and cfg.window_size) else s
        f += 2 * 2 * b * nq * s * ctx * hd * 0.5          # qk^T and pv, causal half
    elif kind == RGLRU:
        w = cfg.rnn_width
        f += 2 * b * s * (2 * d * w + w * d)              # in_x, in_g, out
        f += 2 * b * s * (2 * w * w)                      # gates wa, wx
    elif kind in (MLSTM, SLSTM):
        f += 2 * b * s * d * nq * hd * 4                  # q,k,v,(og|z...) proj
        f += 2 * b * s * nq * hd * d                      # out proj
        if kind == MLSTM:
            L = cfg.chunk_size
            f += 2 * b * s * nq * (L * hd + 2 * hd * hd)  # intra scores + state
        else:
            f += 2 * b * s * nq * hd * hd * 4             # recurrent R matmuls
    if cfg.moe is not None:
        e = cfg.moe
        f += 2 * b * s * d * e.num_experts                # router
        f += 2 * b * s * e.top_k * e.capacity_factor * 3 * d * e.d_ff
        if e.shared_expert:
            f += 2 * b * s * 3 * d * e.d_ff
    elif cfg.d_ff:
        n_mat = 3 if cfg.mlp_kind == "swiglu" else 2
        f += 2 * b * s * n_mat * d * cfg.d_ff
    return f


def model_flops_fwd(cfg: ModelConfig, b: int, s: int,
                    include_encoder: bool = True) -> float:
    f = sum(layer_flops_fwd(cfg, k, b, s) for k in cfg.layer_kinds())
    if cfg.encoder_layers:
        from repro.models.model import ENCODER_FRAMES
        if include_encoder:
            f += cfg.encoder_layers * layer_flops_fwd(
                cfg, ATTN, b, ENCODER_FRAMES)
        # cross-attn: k/v projected from encoder states per decoder layer
        # (every call in this implementation), + q/o on the decoder side
        f += cfg.num_layers * 2 * b * ENCODER_FRAMES * 2 * cfg.d_model \
            * cfg.num_kv_heads * cfg.head_dim
        f += cfg.num_layers * 2 * b * s * (
            cfg.d_model * cfg.num_heads * cfg.head_dim * 2
            + 2 * cfg.num_heads * ENCODER_FRAMES * cfg.head_dim)
    f += 2 * b * s * cfg.d_model * cfg.vocab_size         # logits
    return f


def d_cross(cfg: ModelConfig) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    return d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) / 2


def model_flops_train(cfg: ModelConfig, b: int, s: int) -> float:
    """fwd + bwd = 3x fwd (matmul-only convention, as the paper)."""
    return 3.0 * model_flops_fwd(cfg, b, s)


def model_flops_6nd(cfg: ModelConfig, b: int, s: int) -> float:
    """MODEL_FLOPS = 6*N*D with N = active params (MoE: routed top-k only),
    used as the roofline 'useful compute' reference."""
    n_active = cfg.param_count()
    if cfg.moe is not None:
        e = cfg.moe
        routed_all = cfg.num_layers * e.num_experts * 3 * cfg.d_model * e.d_ff
        routed_active = cfg.num_layers * e.top_k * 3 * cfg.d_model * e.d_ff
        n_active = n_active - routed_all + routed_active
    # embeddings don't do matmul work per token; subtract the table
    n_active -= cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_active += cfg.vocab_size * cfg.d_model  # unembed matmul is real compute
    return 6.0 * n_active * b * s
