"""Analytical per-stage memory model for pipeline-parallel training.

Activation-per-layer formulas follow Korthikanti et al. ("Reducing
Activation Recomputation in Large Transformer Models"), which the paper
cites for its recompute arms. All sizes in bytes, bf16 activations,
sequence parallelism enabled (as the paper's runs: "enabled sequence
parallelism technique").

Attention arms (paper Table 3):
  none      - full activations:        s*b*h*(34 + 5*a*s/h) / t
  recompute - attention recomputed:    s*b*h*34 / t
  flash     - flash attention stores no s^2 intermediates: same 34sbh/t
              (plus the small log-sum-exp, ignored like the paper does)

Param/optimizer state: mixed-precision Adam = 18 bytes/param
(bf16 param+grad: 4, fp32 master+m+v: 12, +2 slack for fp32 grad accum
on the way into the optimizer — Megatron's distributed-optimizer-off
configuration, matching the paper's setup).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Union

from repro.configs.base import ModelConfig
from repro.core import plan as P
from repro.core import schedule as sched
from repro.core.notation import Notation

BYTES_PER_PARAM = 18.0

#: Schedule selector: a compiled-plan ``ScheduleSpec`` (preferred) or a
#: legacy kind name combined with the (v, cap) knob arguments.
KindOrSpec = Union[str, P.ScheduleSpec]


def _as_spec(kind: KindOrSpec, n: Notation, v: int = 1,
             cap: int = None) -> P.ScheduleSpec:
    """Normalize the legacy (kind, v, cap) knobs to a bound spec; a spec
    passed directly wins (its m is bound from the notation if unbound)."""
    if isinstance(kind, P.ScheduleSpec):
        assert kind.p == n.p, f"spec p={kind.p} != notation p={n.p}"
        return kind if kind.bound else kind.with_m(n.num_micro)
    return P.ScheduleSpec(kind, n.p, n.num_micro, v=max(v, 1), cap=cap)


def act_bytes_per_layer(n: Notation, attention: str) -> float:
    """Stashed activation bytes per layer per microbatch."""
    base = 34.0 * n.s * n.b * n.h / n.t
    if attention == "none":
        base += 5.0 * n.a * n.s * n.s * n.b / n.t
    elif attention in ("recompute", "flash"):
        pass
    else:
        raise ValueError(attention)
    return base


def act_bytes_per_stage(n: Notation, attention: str, v: int = 1) -> float:
    """One stash unit's bytes for one (virtual) stage: l/(p*v) layers +
    the boundary input activation (2sbh/t). v > 1 models interleaved
    schedules, whose units each hold 1/v of the device's layers — more
    units in flight, each proportionally smaller."""
    layers = n.l / (n.p * v)
    return layers * act_bytes_per_layer(n, attention) + 2.0 * n.s * n.b * n.h / n.t


def kv_bytes_per_slice(n: Notation, v: int = 1,
                       seq_chunks: int = 1) -> float:
    """Post-RoPE (k, v) bytes ONE sequence slice retains per (virtual)
    stage for later slices' causal attention: 4*(s/c)*b*h/t per layer
    (k + v, bf16, kv heads folded into h). This is the new dominant
    long-context term sequence slicing trades the 34sbh/t stash for."""
    layers = n.l / (n.p * v)
    return layers * 4.0 * n.s * n.b * n.h / (n.t * seq_chunks)


def sliced_unit_bytes(n: Notation, attention: str, v: int = 1,
                      seq_chunks: int = 1) -> float:
    """One stash unit's bytes under sequence slicing: 1/c of the stage
    stash plus the retained-KV prefix the slice's vjp holds, charged at
    the worst slice (c - 1 earlier slices — a uniform weight, so the
    compiled plan's unit counts stay the accounting currency). At
    seq_chunks=1 this is exactly ``act_bytes_per_stage``."""
    c = seq_chunks
    base = act_bytes_per_stage(n, attention, v) / c
    if c == 1:
        return base
    return base + (c - 1) * kv_bytes_per_slice(n, v, c)


#: bf16 param + grad bytes/param for a TIED embedding table's far-stage
#: replica: the fp32 master weight and Adam moments live with the
#: stage-0 owner (Megatron keeps one optimizer copy of a tied table and
#: all-reduces its grad), so the last stage pays only the working copy.
TIED_REPLICA_BYTES_PER_PARAM = 4.0


def vocab_param_count(n: Notation, cfg: ModelConfig = None) -> float:
    """Total embedding + LM-head parameters across their copies (ONE
    table when ``cfg.tie_embeddings``, two otherwise; the GPT-like
    fallback assumes untied like its historical ``2vh`` term). This is
    the share ``param_bytes_per_stage`` no longer spreads uniformly —
    ``vocab_bytes_per_stage`` charges it to the stages that hold it."""
    if cfg is not None:
        return float(cfg.vocab_size) * cfg.d_model \
            * (1 if cfg.tie_embeddings else 2)
    return 2.0 * n.v * n.h


def param_bytes_per_stage(n: Notation, cfg: ModelConfig = None) -> float:
    """Parameter + grad + optimizer bytes per device for one stage's
    transformer *blocks*. Embedding/LM-head state is NOT in here: it
    lives on the boundary stages (stage 0 / stage p-1), which the old
    uniform ``param_count()/p`` spread hid — ``vocab_bytes_per_stage``
    charges it where it sits."""
    if cfg is not None:
        params = (cfg.param_count() - vocab_param_count(n, cfg)) / n.p / n.t
    else:
        # GPT-like: 12 l h^2 block params, evenly striped over stages
        params = 12.0 * n.l * n.h**2 / (n.p * n.t)
    return params * BYTES_PER_PARAM


def logits_bytes(n: Notation) -> float:
    """The fp32 ``(b, s/t, v)`` logits tensor ``models/model.py``
    materializes for the cross-entropy (``loss_fn``'s
    ``logits.astype(float32)``) — a last-stage activation spike the
    34sbh/t stash accounting never sees. Charged as ONE live copy: the
    bf16 projection is transient and the softmax/logsumexp reductions
    happen in place along the vocab dim."""
    return 4.0 * n.b * n.s * n.v / n.t


def vocab_bytes_per_stage(n: Notation, cfg: ModelConfig = None,
                          vocab_parallel: int = 1) -> List[float]:
    """Per-stage embedding / LM-head / logits bytes — the first/last
    stage vocab spike, made visible (and splittable).

    Layout at ``vocab_parallel=1``: stage 0 holds the embedding table's
    full param+grad+optimizer state; stage p-1 holds the LM head's (a
    bf16 param+grad replica only when the table is tied — see
    ``TIED_REPLICA_BYTES_PER_PARAM``) plus the fp32 logits activation.
    ``p == 1`` stacks everything on the single stage (a tied table is
    one tensor, charged once).

    ``vocab_parallel=vp > 1`` (arxiv 2411.05288 direction) scatters the
    table's vocab rows over the FIRST vp stages and the head's rows +
    the logits shards over the LAST vp stages, 1/vp each; overlapping
    ranges simply add. The traffic this buys back is priced by
    ``vocab_collective_bytes`` / the simulator's boundary charge."""
    p = n.p
    tied = cfg.tie_embeddings if cfg is not None else False
    table = (float(cfg.vocab_size) * cfg.d_model if cfg is not None
             else float(n.v) * n.h) / n.t
    state = table * BYTES_PER_PARAM
    out = [0.0] * p
    if p == 1:
        out[0] = state + (0.0 if tied else state) + logits_bytes(n)
        return out
    vp = max(1, min(vocab_parallel, p))
    head_state = table * TIED_REPLICA_BYTES_PER_PARAM if tied else state
    for i in range(vp):
        out[i] += state / vp
    for i in range(p - vp, p):
        out[i] += (head_state + logits_bytes(n)) / vp
    return out


def vocab_collective_bytes(n: Notation, vocab_parallel: int = 1) -> float:
    """Link bytes ONE vocab-parallel collective moves per participating
    rank: a ring all-reduce/gather of the bf16 ``(b, s, h)`` boundary
    activation over vp ranks costs ``2(vp-1)/vp`` times the tensor
    (2sbh/t bytes). The embedding side pays one per microbatch forward
    (partial-lookup all-reduce), the head side one per forward (input
    gather) and one per backward (input-grad reduce-scatter); the
    simulator prices them symmetrically on boundary-stage F/B. 0 at
    ``vocab_parallel <= 1`` — no scatter, no collective."""
    vp = vocab_parallel
    if vp <= 1:
        return 0.0
    return 2.0 * (vp - 1) / vp * 2.0 * n.s * n.b * n.h / n.t


@dataclasses.dataclass
class StageMemory:
    stage: int
    peak_stash: int           # activations held at peak (incl. foreign)
    act_bytes: float
    param_bytes: float
    host_bytes: float = 0.0   # host-DRAM bytes at peak (host_offload)
    vocab_bytes: float = 0.0  # embedding/head state + fp32 logits share

    @property
    def total(self) -> float:
        return self.act_bytes + self.param_bytes + self.vocab_bytes


def per_stage_memory(n: Notation, attention: str, kind: KindOrSpec,
                     cfg: ModelConfig = None, v: int = 1,
                     cap: int = None, template: bool = False
                     ) -> List[StageMemory]:
    """Peak memory per pipeline stage under the given schedule variant
    (a ``ScheduleSpec``, or the legacy kind/v/cap knobs). Stash-unit
    counts come from the compiled plan's peak accounting; for interleaved
    kinds each unit is byte-weighted at 1/v of the device's layers.

    Residency policies change what a *released* unit costs: units
    spilled off the device store (``Schedule.peak_spilled``) are charged
    the policy's ``retained_bytes`` on the device (the boundary input
    for selective_recompute, nothing for host_offload — whose full unit
    bytes land in ``host_bytes`` instead).

    Transfer-overlap depth (``spec.depth``, docs/transfer.md) buys its
    overlap with memory: a data-moving policy at depth d may hold up to
    d in-flight restore transients per stage instead of the single one
    the cap already budgets, so stages that restore over a link are
    charged ``(d - 1)`` extra units.

    ``template=True`` compiles the spec's saturation template
    (``plan.peak_template_spec``) instead of the full stream when the
    kind's peak accounting is m-independent past the warmup ramp
    (``ScheduleKind.peak_saturates``) — identical peaks at a fraction of
    the compile cost; the planner's feasibility pass uses it. Byte
    weights are always the real spec's (they never read m)."""
    spec = _as_spec(kind, n, v, cap)
    sch = P.compile_plan(P.peak_template_spec(spec) if template else spec)
    peaks = sch.peak_stash
    spilled = sch.peak_spilled
    pol = spec.policy
    c = spec.seq_chunks
    per_mb = sliced_unit_bytes(n, attention, spec.v, c)
    retained = pol.retained_bytes(n, attention, spec.v)
    if c > 1:
        # a released slice retains 1/c of the policy's usual bytes
        # (recompute's boundary input shrinks with the slice) plus its
        # own KV — the recompute strip keeps (carry, kv) so later
        # slices' forwards can still read the prefix
        retained = retained / c
        if pol.mechanism == "recompute":
            retained += kv_bytes_per_slice(n, spec.v, c)
    pb = param_bytes_per_stage(n, cfg)
    vb = vocab_bytes_per_stage(n, cfg, spec.vocab_parallel)
    out = []
    for i in range(n.p):
        spill = spilled.get(i, 0)
        inflight = ((spec.depth - 1) if pol.moves_data
                    and sch.num_loads.get(i, 0) > 0 else 0)
        out.append(StageMemory(
            stage=i, peak_stash=peaks[i],
            act_bytes=(peaks[i] + inflight) * per_mb + spill * retained,
            param_bytes=pb,
            host_bytes=spill * per_mb if pol.mechanism == "host" else 0.0,
            vocab_bytes=vb[i]))
    return out


def max_stage_bytes(n: Notation, attention: str, kind: KindOrSpec,
                    cfg: ModelConfig = None, v: int = 1,
                    cap: int = None, template: bool = False) -> float:
    return max(s.total
               for s in per_stage_memory(n, attention, kind, cfg, v, cap,
                                         template=template))


def fits(n: Notation, attention: str, kind: KindOrSpec, device_bytes: float,
         cfg: ModelConfig = None, workspace: float = 4 * 1024**3,
         v: int = 1, cap: int = None) -> bool:
    """Does every stage fit in device memory (leaving CUDA/XLA workspace)?"""
    return (max_stage_bytes(n, attention, kind, cfg, v, cap)
            + workspace <= device_bytes)


def max_micro_batch(n: Notation, attention: str, kind: str,
                    device_bytes: float, cfg: ModelConfig = None,
                    v: int = 1) -> int:
    """Largest b (power of two, dividing B) that fits — the quantity BPipe
    unlocks (paper §4: 'we primarily use the reduced device memory to
    increase the micro batch size')."""
    best = 0
    b = 1
    while b <= n.B:
        if n.B % b == 0:
            cand = n.replace(b=b)
            # interleaved streams only exist for m % p == 0 — such a b is
            # ineligible, not an OOM
            if kind in sched.INTERLEAVED and cand.num_micro % cand.p != 0:
                b *= 2
                continue
            if fits(cand, attention, kind, device_bytes, cfg, v=v):
                best = b
        b *= 2
    return best


def eviction_bytes(n: Notation, attention: str, v: int = 1,
                   seq_chunks: int = 1) -> float:
    """Bytes moved per EVICT/LOAD (one stash unit: a microbatch's stage
    stash, 1/v of it for interleaved kinds, or a sequence slice plus its
    retained-KV prefix for sliced schedules)."""
    return sliced_unit_bytes(n, attention, v, seq_chunks)


def traffic_bytes(n: Notation, attention: str, spec: P.ScheduleSpec) -> float:
    """Total link bytes one step of ``spec`` moves.

    Residency part: the release+restore count of the stream actually
    built (``plan.num_moves`` — cap-, v- and residency-aware) times the
    per-unit stash bytes. Covers the partner swap (evictor<->acceptor)
    and host offload (D2H+H2D) alike; 0 when residency moves no data
    (none, or selective_recompute — whose bill is FLOPs, priced by the
    simulator's RECOMPUTE handler).

    Vocab-parallel part: four boundary collectives per microbatch (F+B
    on each of the two boundary stages — ``vocab_collective_bytes``);
    0 at ``vocab_parallel=1``."""
    spec = _as_spec(spec, n)
    total = 4.0 * spec.m * vocab_collective_bytes(n, spec.vocab_parallel)
    if spec.policy.moves_data:
        total += P.num_moves(spec) * eviction_bytes(n, attention, spec.v,
                                                    spec.seq_chunks)
    return total


def balance_report(n: Notation, attention: str) -> Dict[str, List[float]]:
    """1F1B vs BPipe per-stage activation bytes (the Fig.1 story)."""
    out = {}
    for kind in ("1f1b", "bpipe"):
        out[kind] = [s.act_bytes for s in per_stage_memory(n, attention, kind)]
    return out
