"""Pipeline schedules as per-stage instruction streams.

Core ops:
  F(mb)      forward of microbatch mb
  B(mb)      backward of microbatch mb

Residency ops (inserted by ``repro.memory`` policies — docs/memory.md):
  EVICT(mb)      (bpipe_swap) ship mb's stashed activation to the partner
  LOAD(mb)       (bpipe_swap) fetch it back ahead of B(mb)
  OFFLOAD(mb)    (host_offload) copy the stash to host memory (D2H)
  FETCH(mb)      (host_offload) copy it back ahead of B(mb) (H2D)
  DROP(mb)       (selective_recompute) free the vjp residuals, keep the
                 boundary input
  RECOMPUTE(mb)  (selective_recompute) re-run the forward ahead of B(mb)

The streams are *data*. This module holds the stream builders and the
declarative kind registry (``SCHEDULES`` / ``register``); compiling a
stream set into a dispatchable artifact — dependency edges, partner map,
stash bounds, peak accounting — is ``core.plan``'s job, and every
consumer (simulator, executor, memory model, planner) runs off that
compiled ``plan.Schedule``. Registering a kind here is the ONE step that
makes it plannable, simulable, and executable (docs/api.md). Where a
stashed activation *lives* between its F and its B is the orthogonal
residency axis: ``repro.memory.policy`` owns those rewrites and the
registry that extends the op set.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

F, B, EVICT, LOAD = "F", "B", "EVICT", "LOAD"
OFFLOAD, FETCH = "OFFLOAD", "FETCH"
DROP, RECOMPUTE = "DROP", "RECOMPUTE"


@dataclasses.dataclass(frozen=True)
class Instr:
    op: str
    mb: int
    chunk: int = 0   # virtual-stage chunk (interleaved schedules only)
    sl: int = 0      # sequence slice (seq_chunks > 1 schedules only)

    def __repr__(self):
        c = f".c{self.chunk}" if self.chunk else ""
        s = f".s{self.sl}" if self.sl else ""
        return f"{self.op}{self.mb}{c}{s}"


Stream = List[Instr]


# Base F/B streams are pure functions of small integer tuples, rebuilt
# for every cap/residency/depth ladder neighbor the planner compiles —
# the cached tuple variants (suffix ``_t``) make that rebuild a lookup.
# The public builders return fresh lists (the historical mutable API);
# in-module consumers (the balanced builders' spill rewrites) read the
# tuples directly and never mutate them.
@functools.lru_cache(maxsize=1024)
def _gpipe_t(p: int, m: int, stage: int,
             seq_chunks: int = 1) -> Tuple[Instr, ...]:
    c = seq_chunks
    return tuple([Instr(F, j, 0, s) for j in range(m) for s in range(c)]
                 + [Instr(B, j, 0, c - 1 - s) for j in range(m)
                    for s in range(c)])


def gpipe(p: int, m: int, stage: int, seq_chunks: int = 1) -> Stream:
    """All forwards, then all backwards. Peak stash = m (m * seq_chunks
    sliced units when the sequence is sliced).

    Sliced forwards run slices in causal order (slice i's attention reads
    the retained KV of slices < i); backwards run slices in REVERSE order
    within each microbatch so the executor can accumulate the prefix-KV
    cotangents in one pass (docs/longcontext.md)."""
    return list(_gpipe_t(p, m, stage, seq_chunks))


@functools.lru_cache(maxsize=1024)
def _one_f_one_b_t(p: int, m: int, stage: int,
                   seq_chunks: int = 1) -> Tuple[Instr, ...]:
    c = seq_chunks
    total = m * c
    warmup = min(p - stage - 1 + (c - 1), total)

    def fwd(k):
        return k // c, k % c              # (mb, sl): causal slice order

    def bwd(k):
        return k // c, c - 1 - k % c      # reverse slice order within mb

    out: Stream = []
    nf = nb = 0
    for _ in range(warmup):
        mb, sl = fwd(nf)
        out.append(Instr(F, mb, 0, sl)); nf += 1
    while nf < total:
        mb, sl = fwd(nf)
        out.append(Instr(F, mb, 0, sl)); nf += 1
        mb, sl = bwd(nb)
        out.append(Instr(B, mb, 0, sl)); nb += 1
    while nb < total:
        mb, sl = bwd(nb)
        out.append(Instr(B, mb, 0, sl)); nb += 1
    return tuple(out)


def one_f_one_b(p: int, m: int, stage: int, seq_chunks: int = 1) -> Stream:
    """Non-interleaved 1F1B (DAPPLE / Megatron default).

    Stage i runs min(p-i-1, m) warmup forwards, then alternates F/B, then
    drains. Peak in-flight stash = min(p - i, m)  — the paper's "stage x
    stores p - x activations" imbalance.

    ``seq_chunks=c`` slices every microbatch into c sequence slices
    (SlimPipe direction): the pipeline unit becomes one slice, forwards
    visit slices in causal order, backwards in reverse order within each
    microbatch, and warmup grows by c - 1 (the extra ramp that keeps the
    last stage's B0 fed). At c=1 this is byte-for-byte the classic
    stream."""
    return list(_one_f_one_b_t(p, m, stage, seq_chunks))


def bpipe_cap(p: int) -> int:
    """BPipe's per-device activation bound: ceil((p+2)/2)."""
    return (p + 2 + 1) // 2


def bpipe_pairs(p: int) -> List[Tuple[int, int]]:
    """(evictor, acceptor) pairs: stage x < floor(p/2) pairs with p-1-x."""
    return [(x, p - 1 - x) for x in range(p // 2)]


def _balance(base: Stream, cap: int) -> Stream:
    """BPipe's continuous balancing over any F/B stream (re-homed to
    ``repro.memory.policy.spill`` — the cap-driven rewrite is shared by
    every residency policy; this wrapper pins the EVICT/LOAD op pair the
    balanced schedule kinds emit)."""
    from repro.memory.policy import spill
    return spill(base, cap, EVICT, LOAD)


def bpipe(p: int, m: int, stage: int, cap: int | None = None,
          seq_chunks: int = 1) -> Stream:
    """BPipe = 1F1B + continuous activation balancing at cap
    ceil((p+2)/2) (Kim et al.). Stages with steady in-flight
    p-stage <= cap never evict (acceptors / middle stages). In steady
    state every forward evicts and every backward reloads — the traffic
    is continuous, which is why overlap (NVLink / 1-hop ICI) is
    load-bearing for BPipe's viability; the simulator charges it.

    ``cap`` overrides the paper's default bound: the planner searches
    over it (looser cap -> fewer evictions but more evictor memory;
    tighter -> the reverse, pushed onto the acceptor). Must be >= 2
    (one live forward plus the in-flight LOAD transient).

    With ``seq_chunks=c``, cap counts sliced units and the default bound
    grows by the extra c - 1 warmup slices (each 1/c the bytes, so the
    byte budget still shrinks — see ``memory_model``).
    """
    cap = bpipe_cap(p) + (seq_chunks - 1) if cap is None else cap
    assert cap >= 2, cap
    return _balance(_one_f_one_b_t(p, m, stage, seq_chunks), cap)


# ---------------------------------------------------------------------------
# Interleaved (virtual-chunk) 1F1B — beyond-paper extension
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1024)
def _one_f_one_b_interleaved_t(p: int, m: int, stage: int,
                               v: int = 2) -> Tuple[Instr, ...]:
    assert v >= 2 and m % p == 0, (v, m, p)
    total = m * v

    def fwd_unit(k):
        group, rem = divmod(k, p * v)
        return rem // p, group * p + rem % p       # (chunk, mb)

    def bwd_unit(k):
        group, rem = divmod(k, p * v)
        return v - 1 - rem // p, group * p + rem % p

    warmup = min((p - stage - 1) * 2 + (v - 1) * p, total)
    out: Stream = []
    nf = nb = 0
    for _ in range(warmup):
        c, mb = fwd_unit(nf)
        out.append(Instr(F, mb, c))
        nf += 1
    while nf < total:
        c, mb = fwd_unit(nf)
        out.append(Instr(F, mb, c))
        nf += 1
        c, mb = bwd_unit(nb)
        out.append(Instr(B, mb, c))
        nb += 1
    while nb < total:
        c, mb = bwd_unit(nb)
        out.append(Instr(B, mb, c))
        nb += 1
    return tuple(out)


def one_f_one_b_interleaved(p: int, m: int, stage: int, v: int = 2) -> Stream:
    """Megatron interleaved 1F1B: device ``stage`` hosts v model chunks
    (virtual stages stage + c*p). Bubble shrinks ~v-fold; warmup stash
    grows to 2(p-stage-1) + (v-1)p + 1 units (each 1/v the layers).
    Requires m % p == 0 and v >= 2."""
    return list(_one_f_one_b_interleaved_t(p, m, stage, v))


def interleaved_peak(p: int, m: int, stage: int, v: int = 2) -> int:
    """In-flight stash units at peak under interleaved 1F1B."""
    return min((p - stage - 1) * 2 + (v - 1) * p, m * v) + 1


def bpipe_interleaved_cap(p: int, v: int = 2) -> int:
    """BPipe bound generalized to v chunks: the pair-summed peak
    2(p-1) + 2(v-1)p + 2 is stage-independent (the same symmetry the
    paper's pairing exploits), so the balanced per-device bound is half
    of it plus the LOAD transient slot."""
    pair_sum = 2 * (p - 1) + 2 * (v - 1) * p + 2
    return (pair_sum + 1) // 2 + 1


def bpipe_interleaved(p: int, m: int, stage: int, v: int = 2,
                      cap: int | None = None) -> Stream:
    """BPipe x interleaved-1F1B composition (not in either paper): the
    same evict-newest/load-before-backward balancing applied to
    (chunk, mb) units, bounded by ``bpipe_interleaved_cap`` (or a
    planner-chosen ``cap`` override, >= 2)."""
    cap = bpipe_interleaved_cap(p, v) if cap is None else cap
    assert cap >= 2, cap
    return _balance(_one_f_one_b_interleaved_t(p, m, stage, v), cap)


# ---------------------------------------------------------------------------
# The kind registry — one declarative entry per schedule kind
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScheduleKind:
    """Everything the rest of the system needs to know about a schedule
    kind. Registering one of these (``register``) makes the kind
    compilable (``plan.compile_plan``), plannable (``planner.space``),
    simulable, and executable — no interpreter edits.

    Fields:
      name:        registry key (``ScheduleSpec.kind``).
      builder:     per-stage stream builder. Signature by flags:
                   ``(p, m, stage)`` plain, ``+ v`` if interleaved,
                   ``+ cap=None`` keyword if balanced.
      interleaved: streams carry virtual-chunk instructions (v >= 2,
                   m % p == 0, p*v <= num_layers).
      balanced:    BPipe family — emits EVICT/LOAD under a stash cap and
                   accepts a ``cap`` override.
      sliced:      the builder accepts a ``seq_chunks`` keyword and emits
                   per-sequence-slice units (docs/longcontext.md).
                   ``ScheduleSpec`` normalizes seq_chunks to 1 for kinds
                   without it. Interleaved kinds cannot slice: the
                   sliced warmup ramp deadlocks against the chunk-major
                   unit order.
      default_cap: ``(p, v) -> int`` — the kind's default stash bound
                   (balanced kinds only). Sliced caps count slice units;
                   the builder/spec add the (seq_chunks - 1) warmup
                   allowance so this signature stays (p, v).
      cap_roof:    ``(p, m, v) -> int`` — the cap above which balancing
                   degenerates to the unbalanced twin; bounds the
                   planner's cap search (balanced kinds only).
      peak_saturates: per-stage peak stash/spill accounting is
                   m-independent once m passes the warmup ramp
                   (``plan.PEAK_SATURATION_FACTOR * p * seq_chunks``) —
                   true for the 1F1B cadence family, false for
                   all-forwards-first shapes like gpipe (peak = m).
                   Opting in lets feasibility-style consumers bind a
                   large-m spec to a small saturation template
                   (``plan.peak_template_spec``) instead of compiling
                   the full stream. Leave False for a new kind unless
                   the property holds (tests/test_planner_bnb.py pins
                   it for the built-ins).
    """
    name: str
    builder: Callable[..., Stream]
    interleaved: bool = False
    balanced: bool = False
    sliced: bool = False
    default_cap: Optional[Callable[[int, int], int]] = None
    cap_roof: Optional[Callable[[int, int, int], int]] = None
    peak_saturates: bool = False

    def __post_init__(self):
        if self.balanced and (self.default_cap is None
                              or self.cap_roof is None):
            raise ValueError(
                f"{self.name}: balanced kinds need default_cap and "
                f"cap_roof — the planner's cap search depends on both")

    def stream(self, p: int, m: int, stage: int, v: int = 1,
               cap: Optional[int] = None, seq_chunks: int = 1) -> Stream:
        """Build stage ``stage``'s raw instruction stream (the normalized
        entry point ``plan.compile_plan`` calls)."""
        kw = {}
        if self.balanced and cap is not None:
            kw["cap"] = cap
        if self.sliced and seq_chunks != 1:
            kw["seq_chunks"] = seq_chunks
        if self.interleaved:
            return self.builder(p, m, stage, v, **kw)
        return self.builder(p, m, stage, **kw)


SCHEDULES: Dict[str, ScheduleKind] = {}

# Kinds whose streams carry virtual-chunk instructions / balance a stash
# cap — derived from the registry, rebuilt on every ``register`` call.
INTERLEAVED: frozenset = frozenset()
BPIPE_FAMILY: frozenset = frozenset()


def _rebuild_derived() -> None:
    global INTERLEAVED, BPIPE_FAMILY
    INTERLEAVED = frozenset(k for k, e in SCHEDULES.items() if e.interleaved)
    BPIPE_FAMILY = frozenset(k for k, e in SCHEDULES.items() if e.balanced)


def register(entry: ScheduleKind, replace: bool = False) -> ScheduleKind:
    """Register a schedule kind. ``replace=False`` guards against
    accidental shadowing. Clears the plan-compile cache so a replaced
    kind cannot serve stale artifacts."""
    if entry.name in SCHEDULES and not replace:
        raise ValueError(f"schedule kind {entry.name!r} already registered")
    SCHEDULES[entry.name] = entry
    _rebuild_derived()
    from repro.core import plan as _plan   # deferred: plan imports us
    _plan.compile_plan.cache_clear()
    return entry


def unregister(name: str) -> None:
    """Remove a registered kind (tests / plugin teardown)."""
    SCHEDULES.pop(name, None)
    _rebuild_derived()
    from repro.core import plan as _plan
    _plan.compile_plan.cache_clear()


for _entry in (
    ScheduleKind("gpipe", gpipe, sliced=True),
    ScheduleKind("1f1b", one_f_one_b, sliced=True, peak_saturates=True),
    ScheduleKind("bpipe", bpipe, balanced=True, sliced=True,
                 peak_saturates=True,
                 default_cap=lambda p, v: bpipe_cap(p),
                 cap_roof=lambda p, m, v: max(min(p, m), 2)),
    ScheduleKind("1f1b_interleaved", one_f_one_b_interleaved,
                 interleaved=True, peak_saturates=True),
    ScheduleKind("bpipe_interleaved", bpipe_interleaved, interleaved=True,
                 balanced=True, peak_saturates=True,
                 default_cap=bpipe_interleaved_cap,
                 cap_roof=lambda p, m, v: max(interleaved_peak(p, m, 0, v),
                                              2)),
):
    SCHEDULES[_entry.name] = _entry
_rebuild_derived()
del _entry


def virtual_stage(stage: int, chunk: int, p: int) -> int:
    """Model-order index of device ``stage``'s chunk ``chunk``: chunk c on
    device s hosts the layer slice of virtual stage c*p + s."""
    return chunk * p + stage


def schedule_cap(kind: str, p: int, v: int = 2,
                 cap: int | None = None,
                 seq_chunks: int = 1) -> int | None:
    """The schedule's per-device stash bound (or the ``cap`` override for
    balanced kinds), or None if unbounded. Sliced schedules
    (seq_chunks > 1) count slice units and widen the default bound by the
    extra warmup slices."""
    entry = SCHEDULES[kind]
    if not entry.balanced:
        return None
    if cap is not None:
        return cap
    base = entry.default_cap(p, v if entry.interleaved else 1)
    if entry.sliced and seq_chunks > 1:
        base += seq_chunks - 1
    return base


# ---------------------------------------------------------------------------
# Legacy knob-tuple entry points — thin shims over ``core.plan``.
# New code should construct a ``plan.ScheduleSpec`` and compile it.
# ---------------------------------------------------------------------------
def _spec(kind: str, p: int, m: int, v: int = 2, cap: int | None = None):
    from repro.core import plan as _plan
    entry = SCHEDULES[kind]
    return _plan.ScheduleSpec(kind, p, m,
                              v=v if entry.interleaved else 1,
                              cap=cap if entry.balanced else None)


def build(kind: str, p: int, m: int, v: int = 2,
          cap: int | None = None) -> Dict[int, Stream]:
    """Per-stage raw instruction streams (legacy view of the compiled
    plan; ``plan.compile_plan(spec).streams`` carries the dep-resolved
    version)."""
    from repro.core import plan as _plan
    return _plan.compile_plan(_spec(kind, p, m, v, cap)).instr_streams()


def stash_trace(streams: Dict[int, Stream], p: int) -> Dict[int, List[int]]:
    """Per-stage trace of LOCAL stashed-activation counts after each event,
    including foreign stashes accepted from the paired evictor (a
    round-robin merge is enough for counting because EVICT/LOAD only move
    stash between fixed pairs)."""
    from repro.core import plan as _plan
    return _plan.stash_accounting(streams, p)[0]


def peak_stash(kind: str, p: int, m: int, v: int = 2,
               cap: int | None = None) -> Dict[int, int]:
    """Peak per-stage stash count (local + accepted foreign). Units are
    (mb, chunk) — for interleaved kinds each unit holds 1/v of the layers,
    so byte-weighting is the memory model's job (see
    ``memory_model.act_bytes_per_stage``). A non-default BPipe ``cap``
    shifts stash between evictors and acceptors; this accounting is what
    the planner's feasibility check consumes."""
    from repro.core import plan as _plan
    return dict(_plan.compile_plan(_spec(kind, p, m, v, cap)).peak_stash)


def num_evictions(p: int, m: int, stage: int, kind: str = "bpipe",
                  v: int = 2, cap: int | None = None) -> int:
    """How many EVICTs ``stage`` performs over a step. Generalized to any
    balanced kind and cap override (``plan.num_moves`` gives the total
    EVICT+LOAD traffic count for a spec)."""
    from repro.core import plan as _plan
    return _plan.compile_plan(_spec(kind, p, m, v, cap)).num_evictions[stage]
