"""BPipe planning: evictor/acceptor pairing, eviction counts, and the
pair-adjacent device layout (paper Fig. 2) adapted to the TPU ICI ring.

On GPUs the pair must share a node to ride NVLink; on a TPU ring/torus the
equivalent constraint is *hop distance 1* on the stage mesh axis. The
interleaved layout [0, p-1, 1, p-2, ...] puts every (x, p-1-x) pair on
neighbouring devices, so each eviction is a single collective_permute hop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import plan as P
from repro.core.schedule import bpipe_pairs


@dataclasses.dataclass(frozen=True)
class BPipePlan:
    p: int
    m: int                       # microbatches
    cap: int
    pairs: Tuple[Tuple[int, int], ...]
    evictions: Tuple[int, ...]   # per-stage eviction count
    stage_to_device: Tuple[int, ...]

    @property
    def partner(self) -> Dict[int, int]:
        d = {}
        for a, b in self.pairs:
            d[a] = b
            d[b] = a
        return d


def pair_adjacent_layout(p: int) -> List[int]:
    """stage -> device index such that every (x, p-1-x) pair is adjacent.

    [0, p-1, 1, p-2, ...]: device 2k hosts stage k, device 2k+1 hosts
    stage p-1-k. For GPU nodes of size >=2 pairs share a node (Fig. 2);
    on a TPU ring they are 1 ICI hop apart.
    """
    layout = [0] * p
    for k in range(p // 2):
        layout[k] = 2 * k
        layout[p - 1 - k] = 2 * k + 1
    if p % 2:
        layout[p // 2] = p - 1
    return layout


def plan(p: int, m: int,
         stage_to_device: Optional[Tuple[int, ...]] = None,
         spec: Optional[P.ScheduleSpec] = None) -> BPipePlan:
    """BPipe plan for p stages / m microbatches. ``stage_to_device``
    overrides the pair-adjacent default — e.g. when the stages are laid
    onto a mesh axis larger than p. ``spec`` selects the exact balanced
    variant (interleaved kind, cap override) so the eviction counts match
    the stream actually built; default is plain BPipe at the paper cap."""
    spec = spec or P.ScheduleSpec("bpipe", p, m)
    assert spec.balanced and (spec.p, spec.m) == (p, m), spec
    compiled = P.compile_plan(spec)
    return BPipePlan(
        p=p, m=m, cap=spec.resolved_cap,
        pairs=tuple(bpipe_pairs(p)),
        evictions=tuple(compiled.num_evictions[i] for i in range(p)),
        stage_to_device=(tuple(stage_to_device) if stage_to_device is not None
                         else tuple(pair_adjacent_layout(p))),
    )


def ring_extent(plan_: BPipePlan) -> int:
    """Size of the device ring the layout maps onto: the extent of
    ``stage_to_device``, NOT p — the mesh axis can be larger than the
    stage count (e.g. 4 stages spread over an 8-device ring)."""
    return max(plan_.stage_to_device) + 1


def hop_distance(plan_: BPipePlan, ring_size: Optional[int] = None) -> Dict[Tuple[int, int], int]:
    """ICI ring hop distance between each evictor/acceptor pair.

    The wraparound arm is measured on the *device* ring (``ring_extent``),
    not on p: with p stages laid onto a larger mesh axis, a p-sized ring
    under- (or negatively!) counted the wrap distance."""
    n = ring_size or ring_extent(plan_)
    out = {}
    for a, b in plan_.pairs:
        da, db = plan_.stage_to_device[a], plan_.stage_to_device[b]
        d = abs(da - db)
        out[(a, b)] = min(d, n - d)
    return out


def node_of(device: int, node_size: int) -> int:
    return device // node_size


def pairs_within_node(plan_: BPipePlan, node_size: int) -> bool:
    """Paper Fig. 2 property: every pair lives on one node (GPU view)."""
    return all(
        node_of(plan_.stage_to_device[a], node_size)
        == node_of(plan_.stage_to_device[b], node_size)
        for a, b in plan_.pairs)
