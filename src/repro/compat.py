"""Version shims for the JAX APIs this repo uses.

The codebase targets the current JAX surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.lax.pvary``,
``pallas.tpu.CompilerParams``); this module resolves each name against the
installed JAX and falls back to the pre-rename equivalent so the same
source runs on 0.4.x containers. Import the shims, never the raw names.
"""
from __future__ import annotations

import jax

# --- shard_map: top-level since jax 0.6, experimental before ---------------
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def pvary(x, axis_names):
    """jax.lax.pvary (explicit replication-varying cast). Older JAX tracks
    replication inside shard_map itself (check_rep), so identity is the
    correct fallback."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is not None:
        return fn(x, axis_names)
    return x


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the installed JAX has them."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)


def set_mesh(mesh):
    """Context manager activating ``mesh`` for PartitionSpec resolution.
    Falls back to the Mesh object itself, which is a context manager
    entering the legacy resource environment on older JAX."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict. Older JAX returns a
    one-element list of per-computation dicts; newer returns the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def tpu_compiler_params(**kwargs):
    """pallas.tpu CompilerParams across the TPUCompilerParams rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
