"""Deterministic synthetic data pipeline.

Serves every assigned input shape: LM token streams (zipf-ish marginals so
losses are non-degenerate), stub vision-patch embeddings (VLM) and stub
audio-frame embeddings (whisper) — the assignment's frontend carve-out.
Batches are reproducible functions of (seed, step) so multi-host shards
can be cut without coordination, and are yielded as numpy so device_put /
jit sharding controls placement.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import ENCODER_FRAMES


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def _tokens(rng, cfg: ModelConfig, shape) -> np.ndarray:
    # zipf-flavoured marginal over the vocab, clipped
    z = rng.zipf(1.3, size=shape)
    return (z % cfg.vocab_size).astype(np.int32)


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """One global training batch: next-token LM data (+ stub frontends)."""
    rng = _rng(dc.seed, step)
    n_text = dc.seq_len - (cfg.num_prefix_embeds if cfg.frontend == "vision" else 0)
    toks = _tokens(rng, cfg, (dc.batch, n_text + 1))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend == "vision":
        batch["prefix_embeds"] = rng.standard_normal(
            (dc.batch, cfg.num_prefix_embeds, cfg.d_model), np.float32)
    if cfg.is_encdec:
        batch["enc_embeds"] = rng.standard_normal(
            (dc.batch, ENCODER_FRAMES, cfg.d_model), np.float32)
    return batch


def iterate(cfg: ModelConfig, dc: DataConfig, steps: int) -> Iterator[Dict[str, np.ndarray]]:
    for step in range(steps):
        yield make_batch(cfg, dc, step)


def make_decode_inputs(cfg: ModelConfig, batch: int, step: int = 0,
                       seed: int = 0) -> Dict[str, np.ndarray]:
    """A batch of next tokens for serve_step."""
    rng = _rng(seed, step)
    return {"token": _tokens(rng, cfg, (batch,))}
