"""Logical sharding rules: param/batch/cache pytrees -> PartitionSpec trees.

Production mesh axes (launch/mesh.py): ("data", "model") single-pod or
("pod", "data", "model") multi-pod. Batch shards over pod+data; weight
matrices shard their wide dimension over "model" (Megatron-style tensor
parallelism — the paper's t axis); MoE experts shard over "model"
(expert parallelism); KV caches shard batch over data and kv-heads over
"model". GSPMD pads non-divisible dims (e.g. 40 heads on 16 devices).

Leaf rules key off the parameter NAME (the convention set by the model
init functions) and are padded with leading None for stacked-layer dims.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

M = "model"

# name -> spec for the *trailing* dims of the leaf.
_PARAM_RULES = {
    # embeddings
    "table": (M, None),          # (vocab, d)
    "unembed": (None, M),        # (d, vocab)
    # attention
    "wq": (None, M, None),       # (d, heads, hd)
    "wk": (None, M, None),
    "wv": (None, M, None),
    "wo": (M, None, None),       # (heads, hd, d) — also matches mlstm/slstm
    "bq": (M, None),
    "bk": (M, None),
    "bv": (M, None),
    # dense mlp (wi/wg: (d, f); wo handled by ndim fallback below)
    "wi": (None, M),
    "wg": (None, M),
    # moe (experts lead): router replicated
    "router": (None, None),
    # recurrent (rglru)
    "in_x": (None, M),
    "in_g": (None, M),
    "out": (M, None),
    "wa": (None, M),
    "wx": (None, M),
    "ba": (M,),
    "bx": (M,),
    "lam": (M,),
    "conv_w": (None, M),
    "conv_b": (M,),
    # xlstm
    "wif": (None, M, None),      # (d, nh, 2)
    "bif": (M, None),
    "wog": (None, M, None),
    "w": (None, None, M, None),  # slstm (4, d, nh, hd)
    "r": (None, M, None, None),  # slstm (4, nh, hd, hd)
    "b": (None, M, None),        # slstm (4, nh, hd)
    # norms
    "scale": (None,),
    "bias": (None,),
}

# Experts-leading MoE weights override by ndim: (E, d, f)/(E, f, d)
_MOE_3D = {"wi": (M, None, None), "wg": (M, None, None), "wo": (M, None, None)}

# ---------------------------------------------------------------------------
# Vocabulary-parallel stage scatter (docs/memory.md "Vocab accounting")
# ---------------------------------------------------------------------------
# The mesh has no pipeline axis (stages are separate jit programs), so
# scattering the embedding table / LM head over pipeline stages is a
# per-stage ROW RANGE plus a within-shard PartitionSpec. With the vocab
# dim consumed by the stage scatter, the tensor-parallel "model" axis
# moves to the other (d_model) dim — the vp=1 rules above keep it on
# vocab.
_VOCAB_STAGE_RULES = {
    "table": (None, M),          # (vocab/vp, d): stage-scattered rows
    "unembed": (M, None),        # (d, vocab/vp): stage-scattered cols
}


def vocab_shard_range(stage: int, p: int, vocab_parallel: int, vocab: int,
                      side: str = "embed") -> Tuple[int, int]:
    """Vocab row range ``[lo, hi)`` stage ``stage`` holds of the
    embedding table (``side="embed"`` — scattered over the FIRST vp
    stages) or the LM head (``side="head"`` — over the LAST vp stages).
    ``(0, 0)`` for non-participating stages; the ranges of the
    participating stages tile ``[0, vocab)`` exactly. At
    ``vocab_parallel=1`` the owner stage holds every row — the classic
    boundary-stage layout the memory model charges."""
    if side not in ("embed", "head"):
        raise ValueError(f"side must be 'embed' or 'head', got {side!r}")
    vp = max(1, min(vocab_parallel, p))
    r = stage if side == "embed" else stage - (p - vp)
    if not 0 <= r < vp:
        return (0, 0)
    return (r * vocab // vp, (r + 1) * vocab // vp)


def vocab_param_spec(name: str, vocab_parallel: int = 1) -> P:
    """Within-shard PartitionSpec for ``table``/``unembed`` under a
    vocab-parallel stage scatter: vp > 1 hands the vocab dim to the
    stage scatter and moves the "model" axis to the d_model dim."""
    if name not in _VOCAB_STAGE_RULES:
        raise KeyError(f"no vocab rule for {name!r}; "
                       f"known: {sorted(_VOCAB_STAGE_RULES)}")
    rule = (_VOCAB_STAGE_RULES if vocab_parallel > 1
            else _PARAM_RULES)[name]
    return P(*rule)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
    return ""


def _in_moe(path) -> bool:
    names = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
    return "ffn" in names and "shared" not in names


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


# Relocations performed by legalize(); launchers surface these because
# EXPERIMENTS.md §Perf HC-5 measured a silent head->head_dim relocation
# costing 100x in prefill collectives (GSPMD replicates the s^2 work).
RELOCATIONS: list = []


def legalize(spec: P, shape, mesh, tag: str = "") -> P:
    """Explicit jit in_shardings must divide evenly (GSPMD only pads
    *propagated* shardings). For each sharded dim that doesn't divide,
    relocate the axis to the next unsharded dim that does (e.g. 40 heads
    on 16 model devices -> shard head_dim instead); else replicate it.
    Every relocation is recorded in RELOCATIONS — on attention head dims
    it is a measured 10-100x collective hazard (pick TP | num_heads!).
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for d, entry in enumerate(entries):
        if entry is None:
            continue
        size = _axis_size(mesh, entry)
        if shape[d] % size == 0:
            continue
        entries[d] = None
        for d2 in range(len(shape) - 1, -1, -1):
            if entries[d2] is None and shape[d2] % size == 0 and d2 != d:
                entries[d2] = entry
                RELOCATIONS.append((tag, tuple(shape), d, d2, entry))
                break
        else:
            RELOCATIONS.append((tag, tuple(shape), d, None, entry))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_spec(path, leaf, mesh=None, moe_axis: str = M) -> P:
    name = _leaf_name(path)
    base: Tuple = _PARAM_RULES.get(name, ())
    trailing = leaf.ndim - _lead_pad(path)
    if name in _MOE_3D and _in_moe(path) and trailing == 3:
        # experts-leading (E, d, f)/(E, f, d). moe_axis="model" = expert
        # parallel (activations all-to-all); moe_axis="data" = ZeRO-3
        # style weight sharding (weights gathered per layer — §Perf
        # lever for small-expert MoEs where weight bytes << token bytes).
        base = tuple(moe_axis if e == M else e for e in _MOE_3D[name])
    if name == "wo" and trailing == 2:
        base = (M, None)  # dense mlp wo: (f, d)
    pad = leaf.ndim - len(base)
    if pad < 0:  # scalar-ish leaf, replicate
        return P()
    spec = P(*([None] * pad + list(base)))
    if mesh is not None:
        spec = legalize(spec, leaf.shape, mesh, tag=name)
    return spec


def _lead_pad(path) -> int:
    """Stacked-layer leading dims: 1 if under blocks['pos*'] (scan stack)."""
    for entry in path:
        if isinstance(entry, jax.tree_util.DictKey) and str(entry.key).startswith("pos"):
            return 1
    return 0


def param_specs(params, mesh=None, moe_axis: str = M) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(p, l, mesh, moe_axis), params)


def param_shardings(params, mesh, moe_axis: str = M) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, moe_axis))


# ---------------------------------------------------------------------------
# Batch / cache
# ---------------------------------------------------------------------------
def batch_axes(mesh) -> Tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_specs(batch, mesh) -> Any:
    ba = batch_axes(mesh)

    def spec(path, leaf):
        return legalize(P(*([ba] + [None] * (leaf.ndim - 1))),
                        leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, batch)


def batch_shardings(batch, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_specs(batch, mesh))


def maybe_constrain(x, *entries):
    """with_sharding_constraint against the ambient abstract mesh; no-op
    outside a mesh context or when dims don't divide (legalized)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    valid = []
    for e in entries:
        if e is None or (isinstance(e, str) and e in mesh.axis_names):
            valid.append(e)
        elif isinstance(e, (tuple, list)):
            sub = tuple(a for a in e if a in mesh.axis_names)
            valid.append(sub if sub else None)
        else:
            valid.append(None)
    spec = legalize(P(*valid), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


_CACHE_RULES = {
    "k": (None, None, M, None),     # (b, n, kv, hd)
    "v": (None, None, M, None),
    "pos": (None, None),            # (b, n)
    "h": (None, M),                 # rglru state (b, w)
    "conv": (None, None, M),        # (b, cw-1, w)
    "C": (None, M, None, None),     # mlstm (b, nh, hd, hd)
    "n": (None, M, None),           # (b, nh, hd)
    "m": (None, M),                 # (b, nh)
    "c": (None, M, None),           # slstm
}
_SLSTM_STATE = {"h": (None, M, None), "n": (None, M, None), "m": (None, M, None)}


# strategy "seq": shard the KV cache's sequence dim over "model" instead
# of kv-heads — flash-decoding-style split-KV (EXPERIMENTS.md §Perf
# lever for the collective-bound decode combos, where few kv heads force
# the legalizer onto head_dim and GSPMD into full rematerialization).
_CACHE_RULES_SEQ = {
    "k": (None, M, None, None),
    "v": (None, M, None, None),
    "pos": (None, M),
}


def cache_spec(path, leaf, mesh, strategy: str = "heads", cfg=None) -> P:
    ba = batch_axes(mesh)
    name = _leaf_name(path)
    rules_tbl = dict(_CACHE_RULES)
    if strategy == "auto":
        # §Perf-measured policy (EXPERIMENTS.md HC-2): under GQA the kv
        # broadcast across a sharded head/head_dim axis makes GSPMD fully
        # rematerialize the cache (gemma2/granite: ~1000x collective
        # blowup) -> split-KV (seq sharding). For MHA (qwen1.5-32b) the
        # classic head/hd sharding wins on memory.
        gqa = cfg is not None and cfg.num_heads != cfg.num_kv_heads
        strategy = "seq" if gqa else "heads"
    if strategy == "seq":
        rules_tbl.update(_CACHE_RULES_SEQ)
    base = rules_tbl.get(name, ())
    # slstm h/n/m are (b, nh, hd): disambiguate by rank
    if name in _SLSTM_STATE and leaf.ndim - _lead_pad(path) == 3:
        base = _SLSTM_STATE[name]
    pad = leaf.ndim - len(base)
    if pad < 0:
        return P()
    spec = [None] * pad + list(base)
    # batch dim is the first dim after any stack padding
    spec[_lead_pad(path)] = ba if ba else None
    return legalize(P(*spec), leaf.shape, mesh)


def cache_specs(cache, mesh, strategy: str = "heads", cfg=None) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: cache_spec(p, l, mesh, strategy, cfg), cache)


def cache_shardings(cache, mesh, strategy: str = "heads", cfg=None) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cache, mesh, strategy, cfg))
