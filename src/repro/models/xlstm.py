"""xLSTM cells (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, sequential scan with recurrent h-feedback).

TPU adaptation: mLSTM training uses a *chunkwise* formulation — intra-chunk
work is dense (L x L) matmuls on the MXU, inter-chunk state flows through a
short ``lax.scan`` — instead of a 1-step-per-token recurrence. The exact
sequential form (``mlstm_sequential``) is kept as the oracle and is what
the decode step uses. All gate bookkeeping is log-space stabilized (m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _winit


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg):
    d, nh, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": _winit(ks[0], (d, nh, hd), d),
        "wk": _winit(ks[1], (d, nh, hd), d),
        "wv": _winit(ks[2], (d, nh, hd), d),
        "wo": _winit(ks[3], (nh, hd, d), nh * hd),
        "wif": _winit(ks[4], (d, nh, 2), d),       # i~, f~ preacts per head
        "bif": jnp.concatenate(
            [jnp.zeros((nh, 1)), 3.0 * jnp.ones((nh, 1))], axis=1).astype(jnp.float32),
        "wog": _winit(ks[5], (d, nh, hd), d),      # output gate
    }


def _mlstm_qkvg(p, x, cfg):
    dt = x.dtype
    scale = 1.0 / np.sqrt(cfg.head_dim)
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt)) * scale
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    gates = jnp.einsum("bsd,dng->bsng", x, p["wif"].astype(dt)).astype(jnp.float32)
    gates = gates + p["bif"]
    li = gates[..., 0]                              # (b, s, nh) log-input preact
    lf = jax.nn.log_sigmoid(gates[..., 1])          # log forget gate
    og = jax.nn.sigmoid(
        jnp.einsum("bsd,dnh->bsnh", x, p["wog"].astype(dt)).astype(jnp.float32))
    return q, k, v, li, lf, og


def init_mlstm_state(cfg, batch):
    nh, hd = cfg.num_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),  # (key, value)
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
    }


def _mlstm_step_core(q, k, v, li, lf, state):
    """One stabilized mLSTM step. q/k/v: (b, nh, hd) fp32; li/lf: (b, nh)."""
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fp = jnp.exp(lf + m - m_new)          # decays; exp(-inf - ...) -> 0 ok
    ip = jnp.exp(li - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bnk,bnkv->bnv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnk,bnk->bn", q, n)), jnp.exp(-m_new))
    h = num / den[..., None]
    return h, {"C": C, "n": n, "m": m_new}


def mlstm_sequential(p, x, cfg, state=None):
    """Oracle: step-by-step scan over time. x: (b, s, d) -> (b, s, nh, hd)."""
    b = x.shape[0]
    q, k, v, li, lf, og = _mlstm_qkvg(p, x, cfg)
    state = state or init_mlstm_state(cfg, b)

    def body(st, inp):
        qt, kt, vt, lit, lft = inp
        h, st = _mlstm_step_core(qt, kt, vt, lit, lft, st)
        return st, h

    xs = (q.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          li.transpose(1, 0, 2), lf.transpose(1, 0, 2))
    state, hs = jax.lax.scan(body, state, xs)
    h = hs.transpose(1, 0, 2, 3) * og[..., :, :]   # (b, s, nh, hd)
    return h.astype(x.dtype), state


def mlstm_chunkwise(p, x, cfg, state=None):
    """Chunkwise-parallel mLSTM (matches mlstm_sequential to fp32 tolerance).

    Chunks of length L: intra-chunk attention-like matmuls + inter-chunk
    state carried by a scan over s/L steps.
    """
    b, s0, d = x.shape
    nh, hd = cfg.num_heads, cfg.head_dim
    L = min(cfg.chunk_size, s0)
    pad = (-s0) % L
    if pad:  # causal: trailing zero-pad never influences earlier outputs
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s0 + pad
    nc = s // L
    q, k, v, li, lf, og = _mlstm_qkvg(p, x, cfg)
    if pad:  # make pad steps state-neutral: f=1 (no decay), i=0 (no write)
        valid = (jnp.arange(s) < s0)[None, :, None]
        li = jnp.where(valid, li, -jnp.inf)
        lf = jnp.where(valid, lf, 0.0)

    qc = jnp.moveaxis(q.reshape(b, nc, L, nh, hd), 3, 2).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nc, L, nh, hd), 3, 2).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    vc = jnp.moveaxis(v.reshape(b, nc, L, nh, hd), 3, 2).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    lic = li.reshape(b, nc, L, nh).transpose(1, 0, 3, 2)        # (nc, b, nh, L)
    lfc = lf.reshape(b, nc, L, nh).transpose(1, 0, 3, 2)

    state = state or init_mlstm_state(cfg, b)
    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_body(st, inp):
        qt, kt, vt, lit, lft = inp                  # (b, nh, L, hd) / (b, nh, L)
        C0, n0, m0 = st["C"], st["n"], st["m"]
        g = jnp.cumsum(lft, axis=-1)                # inclusive decay cumsum
        sj = lit - g                                # s_j = li_j - g_j
        M = jnp.maximum(m0[..., None], jax.lax.cummax(sj, axis=sj.ndim - 1))
        # intra-chunk: D_tj = exp(s_j - M_t), j <= t
        D = jnp.exp(sj[..., None, :] - M[..., :, None])
        D = jnp.where(causal, D, 0.0)
        scores = jnp.einsum("bnth,bnjh->bntj", qt, kt) * D
        num = jnp.einsum("bntj,bnjh->bnth", scores, vt)
        # inter-chunk contributions
        w_inter = jnp.exp(m0[..., None] - M)        # (b, nh, L)
        num = num + w_inter[..., None] * jnp.einsum("bnth,bnhv->bntv", qt, C0)
        qn = jnp.einsum("bnth,bnh->bnt", qt, n0) * w_inter
        qn_intra = jnp.sum(scores, axis=-1)         # sum_j D_tj (q_t . k_j)
        m_tot = g + M
        denom = jnp.maximum(jnp.abs(qn + qn_intra), jnp.exp(-m_tot))
        h = num / denom[..., None]                  # (b, nh, L, hd)
        # end-of-chunk state
        gL = g[..., -1:]                            # (b, nh, 1)
        ML = jnp.maximum(m0, jnp.max(sj, axis=-1))
        m1 = gL[..., 0] + ML
        wC0 = jnp.exp(m0 - ML)   # = exp(m0 + g_L - m1)
        wkj = jnp.exp(gL - g + lit - m1[..., None])  # (b, nh, L)
        C1 = wC0[..., None, None] * C0 + jnp.einsum(
            "bnt,bnth,bntv->bnhv", wkj, kt, vt)
        n1 = wC0[..., None] * n0 + jnp.einsum("bnt,bnth->bnh", wkj, kt)
        return {"C": C1, "n": n1, "m": m1}, h

    state, hs = jax.lax.scan(chunk_body, state, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, nh, hd)
    h = (h * og)[:, :s0]
    return h.astype(x.dtype), state


def apply_mlstm_block(p, x, cfg):
    h, _ = mlstm_chunkwise(p, x, cfg)
    return jnp.einsum("bsnh,nhd->bsd", h, p["wo"].astype(x.dtype))


def apply_mlstm_block_step(p, x, cfg, state):
    """Decode: x (b, 1, d)."""
    q, k, v, li, lf, og = _mlstm_qkvg(p, x, cfg)
    h, state = _mlstm_step_core(
        q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), li[:, 0], lf[:, 0], state)
    h = (h * og[:, 0]).astype(x.dtype)
    return jnp.einsum("bnh,nhd->bd", h, p["wo"].astype(x.dtype))[:, None], state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg):
    d, nh, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    w = _winit(ks[0], (4, d, nh, hd), d)            # z, i, f, o preacts
    r = _winit(ks[1], (4, nh, hd, hd), hd) * 0.5    # recurrent (block-diag/head)
    b = jnp.zeros((4, nh, hd), jnp.float32).at[2].set(3.0)  # forget-bias +3
    return {"w": w, "r": r, "b": b,
            "wo": _winit(ks[2], (nh, hd, d), nh * hd)}


def init_slstm_state(cfg, batch):
    nh, hd = cfg.num_heads, cfg.head_dim
    z = jnp.zeros((batch, nh, hd), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, nh, hd), -jnp.inf)}


def _slstm_step_core(pre_x, r, state):
    """pre_x: (b, 4, nh, hd) input preactivations (bias included)."""
    h0, c0, n0, m0 = state["h"], state["c"], state["n"], state["m"]
    pre = pre_x + jnp.einsum("bnh,gnhj->bgnj", h0, r)
    za, ia, fa, oa = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(za)
    m1 = jnp.maximum(fa + m0, ia)                   # exp-forget-gate variant
    fp = jnp.exp(fa + m0 - m1)
    ip = jnp.exp(ia - m1)
    c1 = fp * c0 + ip * z
    n1 = fp * n0 + ip
    o = jax.nn.sigmoid(oa)
    h1 = o * c1 / jnp.maximum(n1, jnp.exp(-m1))
    return h1, {"h": h1, "c": c1, "n": n1, "m": m1}


def slstm_scan(p, x, cfg, state=None):
    """x: (b, s, d) -> ((b, s, nh, hd), state). Strictly sequential."""
    b = x.shape[0]
    state = state or init_slstm_state(cfg, b)
    pre = jnp.einsum("bsd,gdnh->bsgnh", x.astype(jnp.float32), p["w"]) + p["b"]
    r = p["r"]

    def body(st, pre_t):
        h, st = _slstm_step_core(pre_t, r, st)
        return st, h

    state, hs = jax.lax.scan(body, state, pre.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3).astype(x.dtype), state


def apply_slstm_block(p, x, cfg):
    h, _ = slstm_scan(p, x, cfg)
    return jnp.einsum("bsnh,nhd->bsd", h, p["wo"].astype(x.dtype))


def apply_slstm_block_step(p, x, cfg, state):
    pre = jnp.einsum("bd,gdnh->bgnh", x[:, 0].astype(jnp.float32), p["w"]) + p["b"]
    h, state = _slstm_step_core(pre, p["r"], state)
    out = jnp.einsum("bnh,nhd->bd", h.astype(x.dtype), p["wo"].astype(x.dtype))
    return out[:, None], state
