"""Top-level model: embeddings -> PatternStack -> norm -> logits.

Covers all assigned families:
  * decoder-only LMs (dense / MoE / SSM / hybrid),
  * encoder-decoder (whisper: stub audio-frame embeddings -> encoder,
    tokens -> decoder with cross-attention),
  * VLM (stub vision patch embeddings prepended to the token stream).

API:
  init_params(key, cfg)
  forward(params, batch, cfg, remat=...) -> (logits, aux_loss)
  loss_fn(params, batch, cfg, remat=...) -> (loss, metrics)
  init_decode_state(cfg, batch, max_len)
  prefill(params, batch, cfg, state) -> (logits_last, state)
  decode_step(params, token, pos, state, cfg, enc_states=None)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models.blocks import PatternStack
from repro.models.layers import cdtype, embed, init_embed, init_norm, apply_norm, unembed

ENCODER_FRAMES = 1500  # whisper-style fixed encoder length (stub frontend)


def _stacks(cfg: ModelConfig):
    dec = PatternStack(cfg, cross=cfg.is_encdec)
    enc = None
    if cfg.is_encdec:
        enc = PatternStack(cfg, num_layers=cfg.encoder_layers, pattern=(ATTN,))
    return dec, enc


def init_params(key, cfg: ModelConfig):
    dec, enc = _stacks(cfg)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "embed": init_embed(ks[0], cfg),
        "blocks": dec.init(ks[1]),
        "final_norm": init_norm(cfg),
    }
    if enc is not None:
        p["encoder"] = {"blocks": enc.init(ks[2]), "norm": init_norm(cfg)}
    return p


def encode(params, enc_embeds, cfg):
    """Stub-frontend encoder: enc_embeds (b, frames, d) are precomputed
    frame/patch embeddings (the assignment's carve-out)."""
    _, enc = _stacks(cfg)
    x = enc_embeds.astype(cdtype(cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = enc.apply(params["encoder"]["blocks"], x, positions, causal=False)
    return apply_norm(params["encoder"]["norm"], x)


def _embed_inputs(params, batch, cfg):
    """Token (+ optional prefix) embedding. Returns (x, positions, n_prefix)."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens, cfg)
    n_prefix = 0
    if cfg.frontend == "vision" and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(x.dtype)
        n_prefix = pre.shape[1]
        x = jnp.concatenate([pre, x], axis=1)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, positions, n_prefix


def forward(params, batch, cfg: ModelConfig, *, remat="none"):
    """batch: {tokens (b, s) [, prefix_embeds (b, n, d), enc_embeds]}.
    Returns (logits over token positions, moe aux loss)."""
    dec, _ = _stacks(cfg)
    enc_states = None
    if cfg.is_encdec:
        enc_states = encode(params, batch["enc_embeds"], cfg)
    x, positions, n_prefix = _embed_inputs(params, batch, cfg)
    x, aux = dec.apply(params["blocks"], x, positions,
                       enc_states=enc_states, remat=remat)
    x = apply_norm(params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = unembed(params["embed"], x, cfg)
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat="none"):
    """Next-token cross-entropy in fp32 + MoE aux. labels==-1 is masked.

    Two implementations (EXPERIMENTS.md §Perf lever 1):
      baseline: log_softmax + take_along_axis — the gather over the
        vocab-sharded axis makes GSPMD all-gather the fp32 logits;
      fused (cfg.fused_xent): logsumexp + masked-reduce pick — every
        reduction is over the sharded vocab dim, so the (b, s, v) tensor
        never crosses devices and never materializes gathered.
    """
    logits, aux = forward(params, batch, cfg, remat=remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    # This fp32 (b, s/t, v) cast is the last-stage memory spike
    # memory_model.logits_bytes charges (docs/memory.md "Vocab
    # accounting") — at 151k vocab it rivals a whole stage's stash.
    lf = logits.astype(jnp.float32)
    if cfg.fused_xent:
        lse = jax.nn.logsumexp(lf, axis=-1)
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape,
                                              lf.ndim - 1)
        picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0),
                         axis=-1)
        nll = lse - picked
    else:
        logp = jax.nn.log_softmax(lf, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux
    return total, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    dec, _ = _stacks(cfg)
    return dec.init_state(batch, max_len, cdtype(cfg))


def prefill(params, batch, cfg: ModelConfig, state):
    """Run the full prompt, fill decode state, return last-position logits."""
    dec, _ = _stacks(cfg)
    enc_states = None
    if cfg.is_encdec:
        enc_states = encode(params, batch["enc_embeds"], cfg)
    x, positions, n_prefix = _embed_inputs(params, batch, cfg)
    x, state = dec.prefill(params["blocks"], x, positions, state,
                           enc_states=enc_states)
    x = apply_norm(params["final_norm"], x[:, -1:])
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, state, enc_states


def decode_step(params, token, pos, state, cfg: ModelConfig, enc_states=None):
    """token: (b,) int32; pos: scalar int32 (position being written)."""
    x = embed(params["embed"], token[:, None], cfg)
    dec, _ = _stacks(cfg)
    x, state = dec.decode(params["blocks"], x, pos, state,
                          enc_states=enc_states)
    x = apply_norm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, state
