"""PatternStack: heterogeneous layer stacks as a scan over repeating blocks.

A model's depth is ``num_layers`` layers whose temporal-mixer kinds follow
``cfg.block_pattern`` (e.g. recurrentgemma: (RGLRU, RGLRU, LOCAL)).
Full pattern repetitions are stacked (leading dim ``n_full``) and iterated
with ``lax.scan`` — HLO stays O(pattern), not O(depth), which keeps the
512-device dry-run compiles fast. Remainder layers (depth % pattern) are
unrolled at the end.

Each layer = mixer + optional cross-attention (enc-dec) + FFN (dense or
MoE), pre-norm residual.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL, MLSTM, RGLRU, SLSTM
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm


# ---------------------------------------------------------------------------
# Single-layer init / apply
# ---------------------------------------------------------------------------
def init_layer(key, cfg, kind, *, cross=False):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(cfg)}
    if kind in (ATTN, LOCAL):
        p["mixer"] = attn_mod.init_attention(ks[0], cfg)
    elif kind == RGLRU:
        p["mixer"] = rec_mod.init_rglru_block(ks[0], cfg)
    elif kind == MLSTM:
        p["mixer"] = xlstm_mod.init_mlstm(ks[0], cfg)
    elif kind == SLSTM:
        p["mixer"] = xlstm_mod.init_slstm(ks[0], cfg)
    if cross:
        p["norm_x"] = init_norm(cfg)
        p["cross"] = attn_mod.init_attention(ks[1], cfg, cross=True)
    if cfg.moe is not None:
        p["norm2"] = init_norm(cfg)
        p["ffn"] = moe_mod.init_moe(ks[2], cfg)
    elif cfg.d_ff:
        p["norm2"] = init_norm(cfg)
        p["ffn"] = init_mlp(ks[2], cfg)
    return p


def _apply_mixer(p, x, cfg, kind, positions, *, causal, remat):
    def f(p_, x_):
        if kind in (ATTN, LOCAL):
            out, _ = attn_mod.attention(p_, x_, cfg, positions, kind=kind,
                                        causal=causal)
            return out
        if kind == RGLRU:
            return rec_mod.apply_rglru_block(p_, x_, cfg)
        if kind == MLSTM:
            return xlstm_mod.apply_mlstm_block(p_, x_, cfg)
        if kind == SLSTM:
            return xlstm_mod.apply_slstm_block(p_, x_, cfg)
        raise ValueError(kind)

    if remat == "attn" and kind in (ATTN, LOCAL):
        f = jax.checkpoint(f)
    return f(p, x)


#: Mixer kinds that support sequence slicing (seq_chunks > 1): causal
#: attention over a retained-KV prefix. Recurrent kinds (RGLRU, xLSTM)
#: carry cross-sequence state a slice boundary would sever.
SLICEABLE_KINDS = (ATTN, LOCAL)


def apply_layer_sliced(p, x, cfg, kind, positions, kv_prefix, *,
                       remat="none"):
    """One layer over ONE sequence slice with a retained-KV prefix
    (sequence-sliced schedules, docs/longcontext.md).

    Returns (x, aux_loss, (k, v)) — the slice's own post-RoPE KV, which
    the pipeline executor retains for later slices. Only attention
    mixers (``SLICEABLE_KINDS``) can slice; cross-attention layers
    cannot (the encoder states span the full sequence).
    """
    if kind not in SLICEABLE_KINDS:
        raise ValueError(
            f"seq_chunks > 1 needs attention mixers, got {kind!r}")
    if "cross" in p:
        raise ValueError("seq_chunks > 1 does not support cross-attention")

    def mix(p_, x_, kvp):
        return attn_mod.attention_sliced(p_, x_, cfg, positions, kvp,
                                         kind=kind)

    if remat == "attn":
        mix = jax.checkpoint(mix)
    aux = 0.0
    h, kv = mix(p["mixer"], apply_norm(p["norm1"], x), kv_prefix)
    x = x + h
    if "ffn" in p:
        h, aux = _apply_ffn(p["ffn"], apply_norm(p["norm2"], x), cfg)
        x = x + h
    return x, aux, kv


def _apply_ffn(p, x, cfg):
    if cfg.moe is not None:
        return moe_mod.apply_moe(p, x, cfg)
    return apply_mlp(p, x, cfg), 0.0


def apply_layer(p, x, cfg, kind, positions, *, enc_states=None,
                causal=True, remat="none"):
    """Train/prefill layer. Returns (x, aux_loss)."""
    aux = 0.0
    h = _apply_mixer(p["mixer"], apply_norm(p["norm1"], x), cfg, kind,
                     positions, causal=causal, remat=remat)
    x = x + h
    if "cross" in p:
        xc = apply_norm(p["norm_x"], x)
        x = x + attn_mod.cross_attention(p["cross"], xc, enc_states, cfg)
    if "ffn" in p:
        h, aux = _apply_ffn(p["ffn"], apply_norm(p["norm2"], x), cfg)
        x = x + h
    return x, aux


# ---- per-layer recurrent/KV state ----------------------------------------
def init_layer_state(cfg, kind, batch, max_len, dtype):
    if kind in (ATTN, LOCAL):
        return attn_mod.init_kv_cache(cfg, kind, batch, max_len, dtype)
    if kind == RGLRU:
        return rec_mod.init_rglru_state(cfg, batch, dtype)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def apply_layer_prefill(p, x, cfg, kind, positions, state, *, enc_states=None):
    """Like apply_layer but also fills this layer's decode state."""
    xn = apply_norm(p["norm1"], x)
    if kind in (ATTN, LOCAL):
        h, (k, v) = attn_mod.attention(p["mixer"], xn, cfg, positions, kind=kind)
        new_state = attn_mod.fill_kv_cache(state, k, v)
    elif kind == RGLRU:
        # run the block, then extract terminal recurrence/conv state
        h, new_state = _rglru_prefill(p["mixer"], xn, cfg, state)
    elif kind == MLSTM:
        hh, st = xlstm_mod.mlstm_chunkwise(p["mixer"], xn, cfg)
        h = jnp.einsum("bsnh,nhd->bsd", hh, p["mixer"]["wo"].astype(x.dtype))
        new_state = st
    elif kind == SLSTM:
        hh, st = xlstm_mod.slstm_scan(p["mixer"], xn, cfg)
        h = jnp.einsum("bsnh,nhd->bsd", hh, p["mixer"]["wo"].astype(x.dtype))
        new_state = st
    else:
        raise ValueError(kind)
    x = x + h
    if "cross" in p:
        xc = apply_norm(p["norm_x"], x)
        x = x + attn_mod.cross_attention(p["cross"], xc, enc_states, cfg)
    if "ffn" in p:
        h, _ = _apply_ffn(p["ffn"], apply_norm(p["norm2"], x), cfg)
        x = x + h
    return x, new_state


def _rglru_prefill(p, xn, cfg, state):
    dt = xn.dtype
    u = xn @ p["in_x"].astype(dt)
    g = jax.nn.gelu(xn @ p["in_g"].astype(dt))
    uc = rec_mod._conv_full(p, u)
    h = rec_mod.rglru_scan(p, uc)
    out = (h * g) @ p["out"].astype(dt)
    cw = cfg.conv_width
    conv_tail = u[:, -(cw - 1):]
    pad = cw - 1 - conv_tail.shape[1]
    if pad > 0:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
    new_state = {"h": h[:, -1].astype(jnp.float32), "conv": conv_tail}
    return out, new_state


def apply_layer_decode(p, x, cfg, kind, pos, state, *, enc_states=None):
    """One-token decode. x: (b, 1, d). Returns (x, new_state)."""
    xn = apply_norm(p["norm1"], x)
    if kind in (ATTN, LOCAL):
        h, state = attn_mod.attention_decode(p["mixer"], xn, cfg, state, pos, kind=kind)
    elif kind == RGLRU:
        h, state = rec_mod.apply_rglru_block_step(p["mixer"], xn, cfg, state)
    elif kind == MLSTM:
        h, state = xlstm_mod.apply_mlstm_block_step(p["mixer"], xn, cfg, state)
    elif kind == SLSTM:
        h, state = xlstm_mod.apply_slstm_block_step(p["mixer"], xn, cfg, state)
    else:
        raise ValueError(kind)
    x = x + h
    if "cross" in p:
        xc = apply_norm(p["norm_x"], x)
        x = x + attn_mod.cross_attention(p["cross"], xc, enc_states, cfg)
    if "ffn" in p:
        h, _ = _apply_ffn(p["ffn"], apply_norm(p["norm2"], x), cfg)
        x = x + h
    return x, state


# ---------------------------------------------------------------------------
# PatternStack
# ---------------------------------------------------------------------------
class PatternStack:
    """Static helper describing how num_layers decompose into scanned
    pattern blocks + unrolled remainder layers."""

    def __init__(self, cfg, *, cross=False, num_layers=None, pattern=None):
        self.cfg = cfg
        self.cross = cross
        self.pattern = tuple(pattern or cfg.block_pattern)
        n = num_layers if num_layers is not None else cfg.num_layers
        self.num_layers = n
        self.n_full = n // len(self.pattern)
        self.rem = self.pattern[: n % len(self.pattern)]

    # -- init ---------------------------------------------------------------
    def init(self, key):
        p = {}
        for j, kind in enumerate(self.pattern):
            keys = jax.random.split(jax.random.fold_in(key, j), self.n_full)
            p[f"pos{j}"] = jax.vmap(
                lambda k: init_layer(k, self.cfg, kind, cross=self.cross))(keys)
        for i, kind in enumerate(self.rem):
            p[f"rem{i}"] = init_layer(
                jax.random.fold_in(key, 1000 + i), self.cfg, kind, cross=self.cross)
        return p

    def init_state(self, batch, max_len, dtype):
        st = {}
        n = self.n_full
        for j, kind in enumerate(self.pattern):
            one = init_layer_state(self.cfg, kind, batch, max_len, dtype)
            st[f"pos{j}"] = jax.tree.map(
                lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim), one)
        for i, kind in enumerate(self.rem):
            st[f"rem{i}"] = init_layer_state(self.cfg, kind, batch, max_len, dtype)
        return st

    # -- train / eval forward -------------------------------------------------
    def apply(self, params, x, positions, *, enc_states=None, causal=True,
              remat="none"):
        cfg, pattern = self.cfg, self.pattern

        def block(carry, block_params):
            x, aux = carry
            for j, kind in enumerate(pattern):
                x, a = apply_layer(block_params[f"pos{j}"], x, cfg, kind,
                                   positions, enc_states=enc_states,
                                   causal=causal, remat=remat)
                aux = aux + a
            return (x, aux), None

        if remat == "full":
            blockf = jax.checkpoint(block)
        else:
            blockf = block
        scanned = {k: v for k, v in params.items() if k.startswith("pos")}
        if self.n_full and cfg.scan_blocks:
            (x, aux), _ = jax.lax.scan(blockf, (x, 0.0), scanned)
        elif self.n_full:
            carry = (x, 0.0)
            for i in range(self.n_full):
                carry, _ = blockf(carry, jax.tree.map(lambda a: a[i], scanned))
            x, aux = carry
        else:
            aux = 0.0
        for i, kind in enumerate(self.rem):
            x, a = apply_layer(params[f"rem{i}"], x, cfg, kind, positions,
                               enc_states=enc_states, causal=causal, remat=remat)
            aux = aux + a
        return x, aux

    # -- prefill (forward + build decode state) -------------------------------
    def prefill(self, params, x, positions, state, *, enc_states=None):
        cfg, pattern = self.cfg, self.pattern

        def block(x, xs):
            block_params, block_state = xs
            new_states = {}
            for j, kind in enumerate(pattern):
                x, ns = apply_layer_prefill(
                    block_params[f"pos{j}"], x, cfg, kind, positions,
                    block_state[f"pos{j}"], enc_states=enc_states)
                new_states[f"pos{j}"] = ns
            return x, new_states

        scanned_p = {k: v for k, v in params.items() if k.startswith("pos")}
        scanned_s = {k: v for k, v in state.items() if k.startswith("pos")}
        new_state = dict(state)
        if self.n_full:
            x, ns = self._iterate(block, x, (scanned_p, scanned_s))
            new_state.update(ns)
        for i, kind in enumerate(self.rem):
            x, ns = apply_layer_prefill(
                params[f"rem{i}"], x, cfg, kind, positions,
                state[f"rem{i}"], enc_states=enc_states)
            new_state[f"rem{i}"] = ns
        return x, new_state

    def _iterate(self, block, x, xs):
        """scan or unrolled loop over the stacked block dim (see
        ModelConfig.scan_blocks)."""
        if self.cfg.scan_blocks:
            return jax.lax.scan(block, x, xs)
        ys = []
        for i in range(self.n_full):
            x, y = block(x, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
        return x, stacked

    # -- one-token decode ------------------------------------------------------
    def decode(self, params, x, pos, state, *, enc_states=None):
        cfg, pattern = self.cfg, self.pattern

        def block(x, xs):
            block_params, block_state = xs
            new_states = {}
            for j, kind in enumerate(pattern):
                x, ns = apply_layer_decode(
                    block_params[f"pos{j}"], x, cfg, kind, pos,
                    block_state[f"pos{j}"], enc_states=enc_states)
                new_states[f"pos{j}"] = ns
            return x, new_states

        scanned_p = {k: v for k, v in params.items() if k.startswith("pos")}
        scanned_s = {k: v for k, v in state.items() if k.startswith("pos")}
        new_state = dict(state)
        if self.n_full:
            x, ns = self._iterate(block, x, (scanned_p, scanned_s))
            new_state.update(ns)
        for i, kind in enumerate(self.rem):
            x, ns = apply_layer_decode(
                params[f"rem{i}"], x, cfg, kind, pos, state[f"rem{i}"],
                enc_states=enc_states)
            new_state[f"rem{i}"] = ns
        return x, new_state
