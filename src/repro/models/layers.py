"""Shared building blocks: norms, rotary, FFNs, init helpers.

Pure-functional: params are plain dict pytrees of jnp arrays; every layer
is ``init_*(key, cfg) -> params`` + ``apply(params, x, ...) -> y``.
Compute dtype is bf16 (paper's mixed precision); norms/softmax in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


def softcap(x, cap: float):
    """gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-np.arange(0, half, dtype=np.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., s, half)
    ang = ang[..., None, :]                                   # (..., s, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / FFN
# ---------------------------------------------------------------------------
def _winit(key, shape, in_dim, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(in_dim)).astype(dtype)


def init_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        return {"wi": _winit(ks[0], (d, f), d),
                "wg": _winit(ks[1], (d, f), d),
                "wo": _winit(ks[2], (f, d), f)}
    return {"wi": _winit(ks[0], (d, f), d),
            "wo": _winit(ks[2], (f, d), f)}


def apply_mlp(params, x, cfg):
    dt = x.dtype
    if "wg" in params:  # swiglu
        h = jax.nn.silu(x @ params["wi"].astype(dt)) * (x @ params["wg"].astype(dt))
    else:
        h = jax.nn.gelu(x @ params["wi"].astype(dt))
    return h @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, cfg):
    p = {"table": _winit(key, (cfg.vocab_size, cfg.d_model), cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = _winit(jax.random.fold_in(key, 1),
                              (cfg.d_model, cfg.vocab_size), cfg.d_model)
    return p


def embed(params, tokens, cfg):
    x = params["table"].astype(cdtype(cfg))[tokens]
    if cfg.tie_embeddings:  # gemma-style scaled embeddings
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params, x, cfg):
    if cfg.tie_embeddings:
        logits = x @ params["table"].astype(x.dtype).T
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits
