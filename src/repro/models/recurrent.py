"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

A diagonal linear recurrence => training uses ``jax.lax.associative_scan``
(TPU-friendly: log-depth, no sequential loop); decoding is the single-step
update. Block layout follows Griffin: two branches (conv+RG-LRU | GeLU
gate), merged multiplicatively, projected back to d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _winit

_C = 8.0


def init_rglru_block(key, cfg):
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 7)
    import numpy as np
    # Lambda init so that a = sigmoid(Lambda)^c is in ~(0.9, 0.999)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^{-1}(-log u / c)
    return {
        "in_x": _winit(ks[0], (d, w), d),       # recurrent branch
        "in_g": _winit(ks[1], (d, w), d),       # gate branch
        "out": _winit(ks[2], (w, d), w),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w)) * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wa": _winit(ks[4], (w, w), w),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": _winit(ks[6], (w, w), w),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": lam,
    }


def _gates(p, x):
    """a (decay, fp32) and gated input for the recurrence."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"] + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, gated


def rglru_scan(p, x):
    """Full-sequence RG-LRU via associative scan. x: (b, s, w)."""
    a, gated = _gates(p, x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype)


def rglru_step(p, x, h_prev):
    """One decode step. x: (b, w); h_prev: (b, w) fp32."""
    a, gated = _gates(p, x[:, None, :])
    h = a[:, 0] * h_prev + gated[:, 0]
    return h.astype(x.dtype), h


def _conv_full(p, x):
    """Causal depthwise conv, width cw. x: (b, s, w)."""
    cw = p["conv_w"].shape[0]
    out = x * p["conv_w"][cw - 1].astype(x.dtype)
    for i in range(1, cw):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * p["conv_w"][cw - 1 - i].astype(x.dtype)
    return out + p["conv_b"].astype(x.dtype)


def _conv_step(p, x, conv_state):
    """x: (b, w); conv_state: (b, cw-1, w) holding previous inputs."""
    cw = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, x[:, None, :]], axis=1)  # (b, cw, w)
    out = jnp.einsum("bcw,cw->bw", window, p["conv_w"].astype(x.dtype))
    out = out + p["conv_b"].astype(x.dtype)
    return out, window[:, 1:]


def init_rglru_state(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
    }


def apply_rglru_block(p, x, cfg):
    """Train/prefill path. x: (b, s, d) -> (b, s, d)."""
    dt = x.dtype
    u = x @ p["in_x"].astype(dt)
    g = jax.nn.gelu(x @ p["in_g"].astype(dt))
    u = _conv_full(p, u)
    h = rglru_scan(p, u)
    return (h * g) @ p["out"].astype(dt)


def apply_rglru_block_step(p, x, cfg, state):
    """Decode path. x: (b, 1, d) -> ((b, 1, d), state)."""
    dt = x.dtype
    x1 = x[:, 0]
    u = x1 @ p["in_x"].astype(dt)
    g = jax.nn.gelu(x1 @ p["in_g"].astype(dt))
    u, conv = _conv_step(p, u, state["conv"])
    h, hf = rglru_step(p, u, state["h"])
    out = (h * g) @ p["out"].astype(dt)
    return out[:, None], {"h": hf, "conv": conv}
