"""Attention: GQA/MHA, global or sliding-window, train / prefill / decode.

Three implementations, mirroring the paper's experimental arms (Table 3):
  * ``reference`` — plain jnp einsum attention ("none" in the paper),
  * ``recompute`` — same math under jax.checkpoint (applied at the block
    level, see blocks.py) — the paper's "recompute" arm,
  * ``flash``     — the Pallas flash-attention kernel (paper's
    "flash attn 2" arm). Used in kernel tests/benchmarks; dry-runs use
    the reference path because Pallas on CPU is interpret-only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _winit, apply_norm, cdtype, init_norm, rope, softcap

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def init_attention(key, cfg, cross=False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _winit(ks[0], (d, nq, hd), d),
        "wk": _winit(ks[1], (d, nkv, hd), d),
        "wv": _winit(ks[2], (d, nkv, hd), d),
        "wo": _winit(ks[3], (nq, hd, d), nq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), jnp.float32)
        p["bk"] = jnp.zeros((nkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((nkv, hd), jnp.float32)
    if cfg.qk_norm:
        p["qnorm"] = init_norm(cfg, hd)
        p["knorm"] = init_norm(cfg, hd)
    if cross:
        p = {k: v for k, v in p.items() if k not in ("qnorm", "knorm")}
    return p


def _project_q(p, x, cfg, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if "qnorm" in p:
        q = apply_norm(p["qnorm"], q)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(p, x, cfg, positions):
    dt = x.dtype
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if "knorm" in p:
        k = apply_norm(p["knorm"], k)
    if positions is not None:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def _sdpa(q, k, v, cfg, q_pos, k_pos, *, causal, window):
    """Reference scaled-dot-product attention with additive masking.

    q: (b, sq, nq, hd); k/v: (b, sk, nkv, hd); *_pos: (b, s*) int32.
    Computed in fp32 (the paper's exp-(7) pathology: on GPU this upcast
    chain ran as separate unfused kernels; XLA fuses it — see DESIGN.md).
    """
    b, sq, nq, hd = q.shape
    nkv = k.shape[2]
    m = nq // nkv
    qr = q.reshape(b, sq, nkv, m, hd)
    score_dt = jnp.float32 if cfg.attn_fp32 else q.dtype
    scores = jnp.einsum("bqgmh,bkgh->bgmqk", qr, k).astype(score_dt)
    scores = scores / np.sqrt(hd).astype(score_dt)
    scores = softcap(scores, cfg.attn_softcap)
    mask = jnp.ones((b, 1, 1, sq, k.shape[1]), bool)
    dq = q_pos[:, None, None, :, None]
    dk = k_pos[:, None, None, None, :]
    if causal:
        mask &= dq >= dk
    if window:
        mask &= dq - dk < window
    mask &= dk >= 0  # ring-buffer slots not yet written carry pos=-1
    neg = jnp.asarray(NEG_INF, score_dt)
    scores = jnp.where(mask, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgmqk,bkgh->bqgmh", probs, v)
    return out.reshape(b, sq, nq, hd)


def _flash(q, k, v, cfg, *, causal, window, q_offset=0):
    from repro.kernels import ops  # lazy: kernels are optional at import
    return ops.flash_attention(
        q, k, v, causal=causal, window=window or 0,
        softcap=cfg.attn_softcap, q_offset=q_offset, interpret=True)


def attention(p, x, cfg, positions, *, kind, impl=None, causal=True):
    """Full-sequence (train / prefill) self attention.

    kind: 'attn' (global causal) or 'local_attn' (sliding window).
    causal=False gives bidirectional self-attention (whisper encoder).
    Returns (out, (k, v)) so prefill can build the cache.
    """
    impl = impl or cfg.attn_impl
    q = _project_q(p, x, cfg, positions)
    k, v = _project_kv(p, x, cfg, positions)
    window = cfg.window_size if kind == "local_attn" else 0
    if impl == "flash" and causal:
        out = _flash(q, k, v, cfg, causal=True, window=window)
    else:
        out = _sdpa(q, k, v, cfg, positions, positions, causal=causal, window=window)
    dt = x.dtype
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(dt))
    return out, (k, v)


def attention_sliced(p, x, cfg, positions, kv_prefix, *, kind, impl=None):
    """Self-attention for ONE sequence slice with a retained-KV prefix
    (sequence-sliced schedules, docs/longcontext.md).

    x: (b, L, d) — the slice's tokens, whose global positions are
    ``positions`` (contiguous, starting at the prefix length).
    kv_prefix: (k, v) of shape (b, P, nkv, hd) — post-RoPE keys/values of
    ALL earlier slices (P = 0 for slice 0). The slice attends causally
    over prefix + itself; since the prefix covers global positions
    [0, P) and the slice [P, P+L), key positions are just arange(P+L).

    Returns (out, (k_own, v_own)) — the slice's own post-RoPE KV, which
    the executor retains for later slices' prefixes.
    """
    impl = impl or cfg.attn_impl
    q = _project_q(p, x, cfg, positions)
    k_own, v_own = _project_kv(p, x, cfg, positions)
    pk, pv = kv_prefix
    dt = x.dtype
    k = jnp.concatenate([pk.astype(dt), k_own], axis=1)
    v = jnp.concatenate([pv.astype(dt), v_own], axis=1)
    window = cfg.window_size if kind == "local_attn" else 0
    if impl == "flash":
        out = _flash(q, k, v, cfg, causal=True, window=window,
                     q_offset=int(pk.shape[1]))
    else:
        b, total_k = k.shape[0], k.shape[1]
        k_pos = jnp.broadcast_to(
            jnp.arange(total_k, dtype=jnp.int32)[None], (b, total_k))
        out = _sdpa(q, k, v, cfg, positions, k_pos, causal=True,
                    window=window)
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(dt))
    return out, (k_own, v_own)


def cross_attention(p, x, enc_states, cfg):
    """Decoder->encoder attention (whisper). Projects k/v from the encoder
    hidden states with this layer's weights (no RoPE across modalities)."""
    q = _project_q(p, x, cfg, None)
    k, v = _project_kv(p, enc_states.astype(x.dtype), cfg, None)
    b, sq = x.shape[:2]
    q_pos = jnp.zeros((b, sq), jnp.int32)
    k_pos = jnp.zeros((b, k.shape[1]), jnp.int32)
    out = _sdpa(q, k, v, cfg, q_pos, k_pos, causal=False, window=0)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode step with KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(cfg, kind, batch, max_len, dtype):
    """Global layers cache max_len slots; local layers a ring of window."""
    n = min(cfg.window_size, max_len) if kind == "local_attn" else max_len
    shape = (batch, n, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # position stored in each slot; -1 = empty
        "pos": jnp.full((batch, n), -1, jnp.int32),
    }


def update_kv_cache(cache, k_new, v_new, pos):
    """Write one token (b, 1, nkv, hd) at position ``pos`` (scalar int32)."""
    n = cache["k"].shape[1]
    slot = pos % n
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    b = cache["pos"].shape[0]
    ppos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((b, 1), pos, jnp.int32), slot, axis=1)
    return {"k": k, "v": v, "pos": ppos}


def fill_kv_cache(cache, k_seq, v_seq, start=0):
    """Bulk write a prefill sequence (b, s, nkv, hd) into the cache tail."""
    n = cache["k"].shape[1]
    s = k_seq.shape[1]
    b = k_seq.shape[0]
    if s >= n:  # keep last n positions (ring for local layers)
        k_keep, v_keep = k_seq[:, -n:], v_seq[:, -n:]
        pos = jnp.broadcast_to(jnp.arange(s - n, s, dtype=jnp.int32)[None], (b, n))
        # ring alignment: position p lives at slot p % n
        roll = (s - n) % n
        return {"k": jnp.roll(k_keep, roll, axis=1),
                "v": jnp.roll(v_keep, roll, axis=1),
                "pos": jnp.roll(pos + start, roll, axis=1)}
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_seq, 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_seq, 0, axis=1)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)) + start
    ppos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pos, 0, axis=1)
    return {"k": k, "v": v, "pos": ppos}


def attention_decode(p, x, cfg, cache, pos, *, kind):
    """One-token decode: x (b, 1, d), pos scalar. Returns (out, cache)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _project_q(p, x, cfg, positions)
    k_new, v_new = _project_kv(p, x, cfg, positions)
    cache = update_kv_cache(cache, k_new, v_new, pos)
    window = cfg.window_size if kind == "local_attn" else 0
    out = _sdpa(q, cache["k"], cache["v"], cfg, positions, cache["pos"],
                causal=True, window=window)
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache
