"""Mixture-of-Experts FFN: top-k router + capacity-bounded scatter dispatch.

TPU-native formulation (DESIGN.md §3): tokens are dispatched into a
per-batch-row expert buffer (b, E, C, d) with scatter-drop semantics,
experts run as one batched einsum (MXU-friendly, E shardable over the
``model`` mesh axis => GSPMD inserts the all-to-all), and results are
gathered back and combined with router gates. FLOPs are exactly
``top_k * capacity_factor`` times the dense-equivalent FFN — no dense
all-experts waste.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _winit


def init_moe(key, cfg):
    d, e = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": _winit(ks[0], (d, e.num_experts), d),
        "wi": _winit(ks[1], (e.num_experts, d, e.d_ff), d),
        "wg": _winit(ks[2], (e.num_experts, d, e.d_ff), d),
        "wo": _winit(ks[3], (e.num_experts, e.d_ff, d), e.d_ff),
    }
    if e.shared_expert:
        p["shared"] = {
            "wi": _winit(jax.random.fold_in(ks[4], 0), (d, e.d_ff), d),
            "wg": _winit(jax.random.fold_in(ks[4], 1), (d, e.d_ff), d),
            "wo": _winit(jax.random.fold_in(ks[4], 2), (e.d_ff, d), e.d_ff),
        }
    return p


def capacity(cfg, seq_len: int) -> int:
    e = cfg.moe
    c = int(np.ceil(seq_len * e.top_k / e.num_experts * e.capacity_factor))
    return max(e.top_k, min(c, seq_len * e.top_k))


def route(p, x, cfg):
    """Router in fp32. Returns (gates (b,s,k), experts (b,s,k), aux_loss)."""
    e = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (b, s, E)
    gates, idx = jax.lax.top_k(probs, e.top_k)                  # (b, s, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    f = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e.num_experts, dtype=jnp.float32), axis=2),
        axis=(0, 1))                                            # fraction routed
    pbar = jnp.mean(probs, axis=(0, 1))
    aux = e.num_experts * jnp.sum(f * pbar)
    return gates, idx, aux


def apply_moe(p, x, cfg):
    """x: (b, s, d) -> (y, aux_loss)."""
    e = cfg.moe
    b, s, d = x.shape
    k, E = e.top_k, e.num_experts
    C = capacity(cfg, s)
    gates, idx, aux = route(p, x, cfg)

    # --- position of each (token, k) inside its expert's buffer, per row ---
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)            # (b, s, k, E)
    flatoh = onehot.reshape(b, s * k, E)
    slots = jnp.cumsum(flatoh, axis=1) * flatoh - 1             # (b, s*k, E)
    slot = jnp.sum(slots * flatoh, axis=-1).reshape(b, s, k)    # (b, s, k)
    dropped = slot >= C
    slot = jnp.where(dropped, C, slot)                          # C = drop bin

    # --- dispatch: scatter tokens into (b, E, C, d) ---
    bi = jnp.broadcast_to(jnp.arange(b)[:, None, None], (b, s, k))
    buf = jnp.zeros((b, E, C + 1, d), x.dtype)
    x_rep = jnp.broadcast_to(x[:, :, None, :], (b, s, k, d))
    if cfg.moe_constrained:
        # §Perf (moe_a2a): keep the scatter entirely batch-local (E and C
        # replicated within a data shard), then reshard the dispatched
        # buffer to expert-parallel in ONE step — GSPMD lowers that
        # boundary as the canonical MoE all-to-all instead of gathering
        # the scatter operands across the mesh.
        from repro.sharding.rules import maybe_constrain
        batch_only = lambda t: maybe_constrain(
            t, ("pod", "data"), *([None] * (t.ndim - 1)))
        buf = batch_only(buf)
        x_rep = batch_only(x_rep)
    buf = buf.at[bi, idx, slot].set(x_rep, mode="drop")
    buf = buf[:, :, :C]                                         # drop bin off

    if cfg.moe_constrained:  # expert-parallel boundary: the all-to-all
        buf = maybe_constrain(buf, ("pod", "data"), "model", None, None)

    # --- expert computation: batched einsum, E shardable over "model" ---
    dt = x.dtype
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dt)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dt))
    out = jnp.einsum("becf,efd->becd", h, p["wo"].astype(dt))   # (b, E, C, d)
    if cfg.moe_constrained:
        out = maybe_constrain(out, ("pod", "data"), "model", None, None)

    # --- combine: gather back + weight by gates ---
    out = jnp.pad(out, ((0, 0), (0, 0), (0, 1), (0, 0)))        # drop bin = 0
    y = out[bi, idx, slot]                                      # (b, s, k, d)
    y = jnp.sum(y * gates[..., None].astype(dt), axis=2)        # (b, s, d)

    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["wi"].astype(dt)) * (x @ sp["wg"].astype(dt))
        y = y + hs @ sp["wo"].astype(dt)
    return y, aux * e.router_aux_weight
