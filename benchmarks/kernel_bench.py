"""Kernel microbenchmarks (interpret mode on CPU — relative numbers only)
+ the §3.2 fusion-count analysis: on TPU, XLA fuses the paper's
upcast-scale-softmax-downcast chain into ~1 fusion, so the exp-(7)
pathology that made BPipe look good on GPT-3 cannot occur (DESIGN.md §3).

Columns: name, us_per_call, derived (fusion/kernel counts, speedup).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, iters=5):
    f(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def fusion_count(f, *args) -> int:
    txt = jax.jit(f).lower(*args).compile().as_text()
    return txt.count(" fusion(") + txt.count(" fusion.")


def main(print_csv=True, smoke=False):
    key = jax.random.PRNGKey(0)
    rows = []
    sm_shape = (1, 2, 64, 64) if smoke else (4, 8, 256, 256)
    seqs = (64,) if smoke else (128, 256)

    # --- fused softmax: XLA-fused chain vs Pallas kernel -------------------
    x = jax.random.normal(key, sm_shape, jnp.bfloat16)
    t_unfused = _time(jax.jit(
        lambda x: ops.unfused_softmax_chain(x, 0.125, True)), x)
    t_pallas = _time(jax.jit(
        lambda x: ops.fused_softmax(x, 0.125, True, 128, True)), x)
    nf = fusion_count(lambda x: ops.unfused_softmax_chain(x, 0.125, True), x)
    rows.append(("softmax_xla_chain", t_unfused, f"xla_fusions={nf}"))
    rows.append(("softmax_pallas_interpret", t_pallas,
                 "interpret_mode=1"))

    # --- flash attention vs reference --------------------------------------
    for s in seqs:
        q = jax.random.normal(key, (1, s, 8, 64), jnp.bfloat16)
        k = jax.random.normal(key, (1, s, 2, 64), jnp.bfloat16)
        v = jax.random.normal(key, (1, s, 2, 64), jnp.bfloat16)
        blk = min(s, 128)
        t_ref = _time(jax.jit(lambda q, k, v: ref.flash_attention_ref(
            q, k, v, causal=True)), q, k, v)
        t_fa = _time(jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, True, 0, 0.0, None, blk, blk, True)), q, k, v)
        rows.append((f"flash_attn_ref_s{s}", t_ref, "jnp"))
        rows.append((f"flash_attn_pallas_s{s}", t_fa, "interpret_mode=1"))

    if print_csv:
        for name, us, derived in rows:
            print(f"kernel_bench,{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    main()
