"""Paper Table 5 + §4: the estimation method applied to every adjacent
experiment pair, reproducing the paper's validation (exp 8/7: predicted
1.39 vs observed 1.35) and extending it to all pairs the paper discusses.

Columns: pair, predicted_speedup(eq.4), observed_speedup, gap_pct.
"""
from __future__ import annotations

from repro.core import estimator as E
from repro.core.notation import GPT3_96B, LLAMA_65B

# (x, y) pairs: x = larger-b experiment, y = baseline; paper discusses all
PAIRS = [
    (8, 7, GPT3_96B),    # the paper's headline: 1.39 vs 1.35
    (10, 9, GPT3_96B),   # flash: estimator bound vs observed negative
    (2, 1, LLAMA_65B),
    (3, 2, LLAMA_65B),
    (5, 4, LLAMA_65B),
    (6, 5, LLAMA_65B),
]


def main(print_csv=True, smoke=False):
    out = []
    for x, y, n in (PAIRS[:2] if smoke else PAIRS):
        rx = E.paper_row(x)
        r = E.predicted_vs_observed(n.replace(b=rx.b), x, y)
        out.append((x, y, r))
        if print_csv:
            print(f"table5,exp{x}/exp{y},predicted={r['predicted']:.3f},"
                  f"observed={r['observed']:.3f},gap_pct={r['gap_pct']:.1f}")
    return out


if __name__ == "__main__":
    main()
