"""Vocabulary-parallel planner sweep: does splitting the first/last-stage
vocab spike change the verdict?

For each case the planner runs twice over the SAME candidate axes — once
restricted to the unscattered classic (vocab_parallel=1, exactly today's
engine) and once with the vp ladder open — and the table shows what the
scatter buys: the recommended plan, its simulated makespan/MFU, the
per-stage peak bytes, and whether the recommendation itself moved
(``verdict_changed``). Each case also prints the vp=1 memory *skew* row:
stage-0 / middle / last-stage peak bytes under a reference 1f1b plan,
with the vocab share (embedding state, LM-head state, fp32 logits) split
out — the imbalance ``memory_model.vocab_bytes_per_stage`` makes
visible and ``vocab_parallel`` makes plannable (docs/memory.md "Vocab
accounting").

Cases pair a 151k-vocab config (qwen3-14b) at HBM budgets where the
spike gates feasibility against the paper's 32k-vocab control
(llama-65b at A100-80G, where the verdict must NOT move). The
paper-condition verdicts (Table 3) are untouched by design: the default
``SearchSpace`` stays unscattered; this sweep is where the vp > 1 arm
competes.

Row order is pinned (plain list, declared case order) so
``BENCH_smoke.json`` diffs stay stable.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core import memory_model as MM
from repro.core.notation import Notation, from_model
from repro.planner import SearchSpace, cost_model_for, plan_config, recommend

#: (config name, HBM GiB, attention, vp ladder). Budgets picked where the
#: 151k-vocab spike bites: 14 GiB = nothing unscattered fits (vp turns
#: an infeasible config feasible), 16 GiB = vp unlocks a larger micro
#: batch; llama-65b at 80 GiB is the 32k-vocab control (no change).
CASES: Tuple[Tuple[str, float, str, Tuple[int, ...]], ...] = (
    ("qwen3-14b", 14.0, "recompute", (1, 2, 4, 8)),
    ("qwen3-14b", 16.0, "recompute", (1, 2, 4, 8)),
    ("llama-65b", 80.0, "recompute", (1, 2, 4, 8)),
)

#: Smoke case rides the GPT-like Notation fallback with a deliberately
#: out-sized vocab so the spike dominates at toy scale: the ~1.1 GiB
#: table + 0.125 GiB logits sit on the boundary stages while blocks are
#: ~0.05 GiB/stage. 5 GiB budget = the planner's 4 GiB workspace floor
#: plus room for the scattered layout only — vp=1 must come back
#: infeasible, the vp ladder feasible.
SMOKE_N = Notation(a=4, b=1, h=256, l=16, s=128, v=262_144, B=16, p=4, t=1)
SMOKE_CASES: Tuple[Tuple[str, float, Tuple[int, ...]], ...] = (
    ("smoke-bigvocab", 5.0, (1, 2, 4)),
)


def _plan_cells(prefix: str, rp) -> str:
    if rp is None:
        return (f"{prefix}makespan=-,{prefix}mfu=-,{prefix}peak_gib=-,"
                f"{prefix}plan=infeasible")
    return (f"{prefix}makespan={rp.makespan:.4g},"
            f"{prefix}mfu={100 * rp.mfu:.1f},"
            f"{prefix}peak_gib={rp.feas.peak_gib:.2f},"
            f"{prefix}plan={rp.cand.label().replace(' ', '/')}")


def skew_row(n: Notation, cfg, attention: str) -> dict:
    """Per-stage bytes of a reference unscattered 1f1b plan: the
    boundary-stage vocab spike vs the middle of the pipeline."""
    mems = MM.per_stage_memory(n, attention, "1f1b", cfg)
    mid = n.p // 2
    return {
        "stage0_gib": mems[0].total / 2**30,
        "mid_gib": mems[mid].total / 2**30,
        "last_gib": mems[-1].total / 2**30,
        "vocab0_gib": mems[0].vocab_bytes / 2**30,
        "vocab_last_gib": mems[-1].vocab_bytes / 2**30,
    }


def sweep_case(name: str, n: Notation, cfg, hbm: float, attention: str,
               vps: Tuple[int, ...], print_csv: bool = True) -> List[dict]:
    cost = cost_model_for(cfg)
    base = plan_config(n, cfg, hbm, cost=cost,
                       search=SearchSpace(attentions=(attention,),
                                          vocab_parallels=(1,)))
    scattered = plan_config(n, cfg, hbm, cost=cost,
                            search=SearchSpace(attentions=(attention,),
                                               vocab_parallels=vps))
    b_rp, s_rp = recommend(base, attention), recommend(scattered, attention)
    changed = ((b_rp is None) != (s_rp is None)
               or (b_rp is not None and s_rp is not None
                   and b_rp.cand != s_rp.cand))
    skew = skew_row(n, cfg, attention)
    row = {"case": name, "hbm_gib": hbm / 2**30, "attention": attention,
           "base": b_rp, "scattered": s_rp, "verdict_changed": changed,
           **skew}
    if print_csv:
        print(f"vocab_sweep,{name},hbm_gib={hbm / 2**30:.0f},{attention},"
              + _plan_cells("", s_rp) + "," + _plan_cells("base_", b_rp)
              + f",verdict_changed={int(changed)}"
              + f",stage0_gib={skew['stage0_gib']:.2f}"
              + f",mid_gib={skew['mid_gib']:.2f}"
              + f",last_gib={skew['last_gib']:.2f}"
              + f",vocab0_gib={skew['vocab0_gib']:.2f}"
              + f",vocab_last_gib={skew['vocab_last_gib']:.2f}")
    return [row]


def main(print_csv=True, smoke=False):
    rows = []
    if smoke:
        for name, hbm_gib, vps in SMOKE_CASES:
            rows += sweep_case(name, SMOKE_N, None, hbm_gib * 2**30,
                               "recompute", vps, print_csv)
        return rows
    from repro.configs import get_config
    for name, hbm_gib, attention, vps in CASES:
        cfg = get_config(name)
        n = from_model(cfg, b=1, s=2048, B=128, p=8, t=4)
        rows += sweep_case(name, n, cfg, hbm_gib * 2**30, attention, vps,
                           print_csv)
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
