"""Aggregate the dry-run artifacts into the EXPERIMENTS.md roofline table.

Reads experiments/dryrun/*.json. Columns per (arch, shape):
  compute/memory/collective terms (s), dominant, model_flops/HLO_flops,
  roofline MFU bound.
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DRYRUN = os.path.join(ROOT, "experiments", "dryrun")


def load(mesh="single"):
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        out[(rec["arch"], rec["shape"])] = rec
    return out


def load_variants():
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*__single__*.json"))):
        rec = json.load(open(path))
        if "shape" not in rec:  # pipeline__* records have their own format
            continue
        out[(rec["arch"], rec["shape"], rec.get("variant", "?"))] = rec
    return out


def _emit(tag, key, rec, rows, print_csv):
    r = rec.get("roofline")
    if not r:
        return
    t = r["terms"]
    uf = r.get("useful_fraction")
    rows.append((tag,) + key + (
        t["t_compute"], t["t_memory"], t["t_collective"], t["dominant"], uf))
    if print_csv:
        label = ",".join(key)
        if uf is not None:
            print(f"{tag},{label},t_comp={t['t_compute']:.4g},"
                  f"t_mem={t['t_memory']:.4g},t_coll={t['t_collective']:.4g},"
                  f"dom={t['dominant']},useful={uf:.3f},"
                  f"mfu_bound={r['roofline_mfu']:.3f}")
        else:
            print(f"{tag},{label},incomplete")


def main(print_csv=True, mesh="single", smoke=False):
    # smoke: nothing to shrink — this only aggregates dry-run JSON already
    # on disk (absent artifacts yield zero rows, which is fine offline)
    rows = []
    for (arch, shape), rec in load(mesh).items():
        _emit("roofline", (arch, shape), rec, rows, print_csv)
    # §Perf variants, for before/after comparison against the baselines
    for (arch, shape, variant), rec in load_variants().items():
        _emit("roofline_variant", (arch, shape, variant), rec, rows,
              print_csv)
    return rows


if __name__ == "__main__":
    main()
