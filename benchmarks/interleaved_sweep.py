"""Interleaved-vs-plain schedule sweep: the bubble/memory trade-off the
beyond-paper interleaved kinds buy, and what BPipe balancing claws back.

For each (p, m, v): simulated bubble fraction and makespan for 1F1B,
interleaved 1F1B, and interleaved BPipe (infinite pair bandwidth plus one
finite-bandwidth arm), and peak stash in layer-equivalents (stash units x
1/v layers) with the bpipe_interleaved cap.

Columns: p, m, v, kind, makespan, bubble, peak_units, peak_layer_equiv,
cap_units, load_stall.
"""
from __future__ import annotations

from repro.core import plan as P
from repro.core import simulator as SIM

GRID = [(4, 16), (8, 32), (16, 64)]
VS = (2, 4)


def _row(kind, p, m, v, t_move_rel=0.0):
    spec = P.ScheduleSpec(kind, p, m, v=v)
    res = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=1.0, Tb=2.0, evict_bytes=t_move_rel,
        pair_bw=1.0 if t_move_rel else float("inf")))
    units = max(P.compile_plan(spec).peak_stash.values())
    layer_eq = units / spec.v
    cap = spec.resolved_cap
    return (kind, res.makespan, res.bubble_fraction, units, layer_eq,
            cap if cap is not None else "-", res.load_stall)


def main(print_csv=True, smoke=False):
    rows = []
    grid = GRID[:1] if smoke else GRID
    vs = VS[:1] if smoke else VS
    for p, m in grid:
        cases = [("1f1b", 1, 0.0), ("bpipe", 1, 0.0)]
        for v in vs:
            cases += [("1f1b_interleaved", v, 0.0),
                      ("bpipe_interleaved", v, 0.0),
                      ("bpipe_interleaved", v, 1.0)]
        for kind, v, tm in cases:
            kind_, mk, bub, units, leq, cap, stall = _row(kind, p, m, v, tm)
            rows.append((p, m, v, kind_, mk, bub, units, leq, cap, stall))
            if print_csv:
                arm = f"{kind_}+slowlink" if tm else kind_
                print(f"interleaved_sweep,p={p},m={m},v={v},{arm},"
                      f"makespan={mk:.1f},bubble={bub:.4f},"
                      f"peak_units={units},layer_equiv={leq:.1f},"
                      f"cap={cap},stall={stall:.1f}")
    return rows


if __name__ == "__main__":
    main()
