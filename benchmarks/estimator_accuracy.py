"""§4 estimator accuracy, beyond the paper: eq. 4 vs the discrete-event
simulator across a (p, B, b, eviction-overhead) grid. The simulator plays
the role of ground truth; the gap quantifies exactly what eq. 4 ignores
(BPipe traffic + drain effects) — the paper's own explanation for its
1.39-vs-1.35 residual.

Columns: p, B, bx/by, t_move_rel (transfer/Tf), eq4, simulated, err_pct.
"""
from __future__ import annotations

from repro.core import estimator as E
from repro.core import plan as P
from repro.core import simulator as SIM
from repro.core.notation import Notation

GRID_P = (4, 8, 16)
GRID_B = (64, 128)
GRID_BX = (2, 4)
GRID_TMOVE = (0.0, 1.0, 4.0)  # transfer time relative to Tf


def simulate_mfu(p, m, Tf, kind, t_move):
    cfg = SIM.SimConfig(spec=P.ScheduleSpec(kind, p, m), Tf=Tf, Tb=2 * Tf,
                        evict_bytes=t_move * Tf, pair_bw=1.0)
    res = SIM.simulate(cfg)
    return 1.0 / res.makespan, res


def main(print_csv=True, smoke=False):
    rows = []
    grid_p = GRID_P[:1] if smoke else GRID_P
    grid_b = GRID_B[:1] if smoke else GRID_B
    grid_tm = GRID_TMOVE[:2] if smoke else GRID_TMOVE
    for p in grid_p:
        for B in grid_b:
            for bx in GRID_BX:
                for tm in grid_tm:
                    # stage MFU gain with b: synthetic 10% per doubling
                    mfu_y, mfu_x = 0.45, 0.45 * (1.1 ** (bx - 1).bit_length())
                    n = Notation(a=8, b=bx, h=1024, l=32, s=2048, v=32000,
                                 B=B, p=p, t=1)
                    eq4 = E.speedup(n, bx, 1, mfu_x, mfu_y)
                    # simulator: throughput ratio with per-mb times from MFU
                    Ty = 1.0 / mfu_y
                    Tx = bx / mfu_x          # b tokens per microbatch
                    thr_y, _ = simulate_mfu(p, B, Ty / 3, "1f1b", 0.0)
                    thr_x, res = simulate_mfu(p, B // bx, Tx / 3, "bpipe", tm)
                    sim = thr_x / thr_y
                    err = 100.0 * (eq4 - sim) / sim
                    rows.append((p, B, bx, tm, eq4, sim, err))
                    if print_csv:
                        print(f"estimator_accuracy,p={p},B={B},bx={bx},"
                              f"tmove={tm:.1f},eq4={eq4:.3f},sim={sim:.3f},"
                              f"err_pct={err:.2f}")
    return rows


if __name__ == "__main__":
    main()
