"""Paper Table 3 reproduction: whole-model MFU for all 10 experiments.

For each row we derive the whole-pipeline MFU from that row's single-stage
MFU (Table 5) through eq. 3, then run the discrete-event simulator with
BPipe eviction traffic charged on (a) the paper's A100/NVLink link and
(b) the TPU-v5e ICI link (the hardware-adaptation variant), including the
pair-adjacent 1-hop layout. Columns:

  exp_id, model, b, bpipe, attention, observed_mfu(paper),
  eq3_predicted_mfu, sim_mfu_nvlink, sim_mfu_ici, pred/obs
"""
from __future__ import annotations

from repro.core import estimator as E
from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import simulator as SIM
from repro.core.estimator import PAPER_ROWS
from repro.core.flops import paper_flops, stage_flops
from repro.core.notation import (A100_PEAK_BF16, GPT3_96B, LLAMA_65B,
                                 NVLINK_BW, TPU_V5E_ICI_BW)

NOTATION = {"gpt3-96b": GPT3_96B, "llama-65b": LLAMA_65B}


def row_mfu(row, link_bw: float) -> dict:
    n = NOTATION[row.model].replace(b=row.b)
    F = paper_flops(n.replace(b=n.B))        # full-batch model FLOPs
    Fs = F / n.p
    pred = E.mfu_model(n, F, Fs, row.mfu_stage / 100.0) * 100.0

    # simulator: stage time from the measured single-stage MFU
    # (a stage is a t-GPU group => per-stage peak is t x chip peak)
    T = E.stage_T_from_mfu(n, Fs, row.mfu_stage / 100.0,
                           A100_PEAK_BF16 * n.t)
    spec = P.ScheduleSpec("bpipe" if row.bpipe else "1f1b", n.p, n.num_micro)
    sim_cfg = SIM.SimConfig(
        spec=spec, Tf=T / 3.0, Tb=2.0 * T / 3.0,
        evict_bytes=MM.eviction_bytes(n, row.attention),
        pair_bw=link_bw, pair_hops=1)
    res = SIM.simulate(sim_cfg)
    sim_mfu = SIM.mfu_from_sim(res, F, n.p, n.t, A100_PEAK_BF16) * 100.0
    return {"pred": pred, "sim": sim_mfu, "stall": res.load_stall,
            "makespan": res.makespan}


def main(print_csv=True, smoke=False):
    rows = []
    # smoke keeps one row per (model, schedule) flavor — enough to catch
    # estimator/simulator regressions without the full grid
    for r in (PAPER_ROWS[:3] if smoke else PAPER_ROWS):
        nv = row_mfu(r, NVLINK_BW)
        ici = row_mfu(r, TPU_V5E_ICI_BW)
        rows.append((r, nv, ici))
        if print_csv:
            print(f"table3,exp{r.exp_id},{r.model},b={r.b},"
                  f"bpipe={int(r.bpipe)},{r.attention},"
                  f"obs={r.mfu:.1f},eq3={nv['pred']:.1f},"
                  f"sim_nvlink={nv['sim']:.1f},sim_ici={ici['sim']:.1f},"
                  f"pred_over_obs={nv['pred']/r.mfu:.3f}")
    return rows


if __name__ == "__main__":
    main()
