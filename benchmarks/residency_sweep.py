"""Residency-policy sweep: the paper's three-way contest (§4 / Table 3)
as one mechanism — makespan and peak device memory across
{bpipe_swap, host_offload, selective_recompute, none} on the two paper
configs.

Each arm runs the SAME base schedule and the same cap-driven spill
discipline; only the residency mechanism differs: swap rides the
NVLink-class pair link, offload the PCIe-class host link, recompute the
compute frontier (one extra chunk forward per restore). Peak bytes come
from the residency-aware memory model (spilled units charged their
retained bytes; offloaded bytes reported as host_gib).

Columns: config, attention, b, kind, res, makespan, mfu_rel (vs the
unmanaged 1f1b arm), peak_gib, host_gib, moves, traffic_gib, stall.
"""
from __future__ import annotations

from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import simulator as SIM
from repro.core.notation import (GPT3_96B, LLAMA_65B, NVLINK_BW, PCIE_BW,
                                 Notation)
from repro.planner import cost_model_for

#: (kind, residency) arms — same spill cap, four places for the stash.
ARMS = [("1f1b", "none"), ("bpipe", "bpipe_swap"),
        ("1f1b", "host_offload"), ("1f1b", "selective_recompute")]

CASES = [("gpt3-96b", GPT3_96B, "recompute", 2),
         ("llama-65b", LLAMA_65B, "recompute", 4)]

SMOKE_N = Notation(a=4, b=2, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
SMOKE_CASES = [("smoke", SMOKE_N, "recompute", 2)]


def _arm_row(n: Notation, att: str, b: int, kind: str, res: str,
             cost) -> dict:
    nb = n.replace(b=b)
    spec = P.ScheduleSpec(kind, n.p, nb.num_micro, residency=res)
    T = cost.stage_T(nb, att)
    sim = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=T / 3.0, Tb=2.0 * T / 3.0,
        evict_bytes=(MM.eviction_bytes(nb, att, spec.v)
                     if spec.policy.moves_data else 0.0),
        pair_bw=NVLINK_BW, d2h_bw=PCIE_BW, h2d_bw=PCIE_BW))
    mems = MM.per_stage_memory(nb, att, spec)
    return {
        "spec": spec, "makespan": sim.makespan, "stall": sim.load_stall,
        "peak_gib": max(m.total for m in mems) / 2**30,
        "host_gib": max(m.host_bytes for m in mems) / 2**30,
        "moves": P.num_moves(spec),
        "traffic_gib": MM.traffic_bytes(nb, att, spec) / 2**30,
    }


def main(print_csv=True, smoke=False):
    rows = []
    for name, n, att, b in (SMOKE_CASES if smoke else CASES):
        # the cheap analytic model in smoke; Table 5 curves otherwise
        if smoke:
            cost = cost_model_for(None)
        else:
            from repro.configs import get_config
            cost = cost_model_for(get_config(name))
        base = None
        for kind, res in ARMS:
            r = _arm_row(n, att, b, kind, res, cost)
            if base is None:
                base = r["makespan"]
            rel = base / r["makespan"]
            rows.append((name, att, b, kind, res, r))
            if print_csv:
                print(f"residency_sweep,{name},{att},b={b},{kind},res={res},"
                      f"makespan={r['makespan']:.4g},mfu_rel={rel:.3f},"
                      f"peak_gib={r['peak_gib']:.2f},"
                      f"host_gib={r['host_gib']:.2f},moves={r['moves']},"
                      f"traffic_gib={r['traffic_gib']:.2f},"
                      f"stall={r['stall']:.3g}")
    return rows


if __name__ == "__main__":
    main()
