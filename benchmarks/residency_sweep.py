"""Residency-policy sweep: the paper's three-way contest (§4 / Table 3)
as one mechanism — makespan and peak device memory across
{bpipe_swap, host_offload, selective_recompute, none} on the two paper
configs, plus the transfer-overlap depth axis (docs/transfer.md): every
data-moving arm is swept at depth 1 (serialized classic) and depth 2
(overlapped), so the table shows directly whether hiding the link
changes the arm's verdict.

Each arm runs the SAME base schedule and the same cap-driven spill
discipline; only the residency mechanism differs: swap rides the
NVLink-class pair link, offload the PCIe-class host link (direction
split D2H/H2D), recompute the compute frontier (one extra chunk forward
per restore). Peak bytes come from the residency-aware memory model
(spilled units charged their retained bytes; offloaded bytes reported
as host_gib; depth > 1 charged its in-flight transients).

Row order is pinned: rows are appended to a plain list strictly in the
declared (case x arm x depth) order — never collected through or
re-derived from dict iteration — so ``BENCH_smoke.json`` diffs are
stable across runs and Python builds.

Columns: config, attention, b, kind, res, depth, makespan, mfu_rel (vs
the unmanaged 1f1b arm), peak_gib, host_gib, moves, traffic_gib, stall,
queue_peak.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import simulator as SIM
from repro.core.notation import (GPT3_96B, LLAMA_65B, NVLINK_BW, PCIE_BW,
                                 Notation)
from repro.planner import cost_model_for

#: (kind, residency, depth) arms — same spill cap, four places for the
#: stash; data-moving mechanisms additionally swept over overlap depth.
ARMS: Tuple[Tuple[str, str, int], ...] = (
    ("1f1b", "none", 1),
    ("bpipe", "bpipe_swap", 1),
    ("bpipe", "bpipe_swap", 2),
    ("1f1b", "host_offload", 1),
    ("1f1b", "host_offload", 2),
    ("1f1b", "selective_recompute", 1),
)

CASES = [("gpt3-96b", GPT3_96B, "recompute", 2),
         ("llama-65b", LLAMA_65B, "recompute", 4)]

SMOKE_N = Notation(a=4, b=2, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
SMOKE_CASES = [("smoke", SMOKE_N, "recompute", 2)]


def _arm_row(n: Notation, att: str, b: int, kind: str, res: str,
             depth: int, cost) -> dict:
    nb = n.replace(b=b)
    spec = P.ScheduleSpec(kind, n.p, nb.num_micro,
                          residency="none" if res == "bpipe_swap" else res,
                          depth=depth)
    T = cost.stage_T(nb, att)
    sim = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=T / 3.0, Tb=2.0 * T / 3.0,
        evict_bytes=(MM.eviction_bytes(nb, att, spec.v)
                     if spec.policy.moves_data else 0.0),
        pair_bw=NVLINK_BW, d2h_bw=PCIE_BW, h2d_bw=PCIE_BW))
    mems = MM.per_stage_memory(nb, att, spec)
    return {
        "spec": spec, "makespan": sim.makespan, "stall": sim.load_stall,
        "peak_gib": max(m.total for m in mems) / 2**30,
        "host_gib": max(m.host_bytes for m in mems) / 2**30,
        "moves": P.num_moves(spec),
        "traffic_gib": MM.traffic_bytes(nb, att, spec) / 2**30,
        "queue_peak": sim.queue_peak,
    }


def main(print_csv=True, smoke=False):
    cases = SMOKE_CASES if smoke else CASES
    # Rows accumulate in a plain list strictly in the declared
    # (case x arm) order, so the emitted order and BENCH_smoke.json
    # diffs are stable across runs and Python builds. The unmanaged
    # 1f1b arm is declared first per case and anchors every arm's
    # relative MFU.
    rows: List[Tuple[str, str, int, str, str, int, dict]] = []
    for name, n, att, b in cases:
        if smoke:
            cost = cost_model_for(None)     # cheap analytic model
        else:
            from repro.configs import get_config
            cost = cost_model_for(get_config(name))
        base_makespan = None
        for kind, res, depth in ARMS:
            r = _arm_row(n, att, b, kind, res, depth, cost)
            if (kind, res) == ("1f1b", "none"):
                base_makespan = r["makespan"]
            rel = base_makespan / r["makespan"]
            rows.append((name, att, b, kind, res, depth, r))
            if print_csv:
                print(f"residency_sweep,{name},{att},b={b},{kind},res={res},"
                      f"depth={depth},"
                      f"makespan={r['makespan']:.4g},mfu_rel={rel:.3f},"
                      f"peak_gib={r['peak_gib']:.2f},"
                      f"host_gib={r['host_gib']:.2f},moves={r['moves']},"
                      f"traffic_gib={r['traffic_gib']:.2f},"
                      f"stall={r['stall']:.3g},"
                      f"queue_peak={r['queue_peak']}")
    return rows


if __name__ == "__main__":
    main()
