"""Sim-vs-real divergence audit on the paper's two model shapes.

Runs ``repro.obs.compare.audit`` end to end for gpt3-96b and llama-65b
(reduced shapes — the audit runs the REAL executor, traced, then
re-simulates the same ``ScheduleSpec`` under trace-fitted costs): the
schedule the simulator priced and the schedule the runtime executed are
aligned span-by-span. The rows quantify the paper's §4 premise — that
the discrete-event model predicts the real pipeline — as three numbers
per run: census match (identical instruction sets), per-op time skew
(F/B share of the step, real vs simulated), and per-stage ordering
divergence (normalized inversions of the dispatch order).

Also publishes ``LAST_METRICS`` — bubble fraction, peak HBM bytes and
channel occupancy folded from the real trace by ``repro.obs.metrics`` —
which ``benchmarks/run.py`` copies into ``BENCH_smoke.json`` so CI runs
leave a perf-trajectory data point per audit.

Columns: config, kind, b, m, sim_n, real_n, census, time_scale,
skew_F, skew_B, max_order_div, bubble_pct, peak_hbm_mib, chan_occ.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

#: Filled by ``main`` — per-config observability summary for the
#: orchestrator's JSON report.
LAST_METRICS: Optional[Dict[str, Dict[str, float]]] = None

#: (config, kind, cap) audit arms; bpipe exercises the EVICT/LOAD
#: channel spans, so the audit covers the transfer path too.
CASES: Tuple[Tuple[str, str, int], ...] = (
    ("gpt3-96b", "bpipe", 2),
    ("llama-65b", "bpipe", 2),
)


def _audit_case(name: str, kind: str, cap: int, layers: int,
                m: int, seq: int) -> Tuple[dict, Dict[str, float]]:
    from repro.configs import get_config
    from repro.core import plan as P
    from repro.obs import compare, metrics
    from repro.obs.events import Recorder
    from repro.pipeline import executor as ex_mod

    cfg = dataclasses.replace(get_config(name).reduced(),
                              num_layers=layers, dtype="float32")
    spec = P.ScheduleSpec(kind, 4, m, cap=cap)
    rep = compare.audit(cfg, spec, micro_batch=1, seq=seq)
    # Re-run the traced step once more for the metrics fold: audit()
    # already proved the streams align, so one representative trace is
    # enough for the summary numbers.
    import jax
    from repro.models import model as M
    ex = ex_mod.PipelineExecutor(cfg, spec=spec, micro_batch=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (m, seq + 1),
                              0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ex.step(params, batch)
    rec = Recorder()
    ex.step(params, batch, trace=True, observer=rec)
    met = metrics.compute(rec.spans, p=spec.p)
    skews = {s.op: s.skew for s in rep.op_skew}
    row = {
        "config": name, "kind": kind, "b": 1, "m": m,
        "sim_n": rep.sim_count, "real_n": rep.real_count,
        "census": int(rep.instruction_sets_match),
        "time_scale": rep.time_scale,
        "skew_F": skews.get("F", 0.0), "skew_B": skews.get("B", 0.0),
        "max_order_div": rep.max_order_divergence,
        "bubble_pct": 100.0 * met.bubble_fraction,
        "peak_hbm_mib": met.hbm_peak / 2**20,
        "chan_occ": met.channel_occupancy(),
    }
    summary = {
        "bubble_pct": row["bubble_pct"],
        "peak_hbm_bytes": met.hbm_peak,
        "channel_occupancy": row["chan_occ"],
        "time_scale": rep.time_scale,
        "max_order_divergence": rep.max_order_divergence,
        "census_match": float(rep.instruction_sets_match),
    }
    return row, summary


def main(print_csv=True, smoke=False):
    global LAST_METRICS
    layers, m, seq = (4, 8, 16) if smoke else (8, 8, 32)
    rows: List[dict] = []
    LAST_METRICS = {}
    for name, kind, cap in CASES:
        row, summary = _audit_case(name, kind, cap, layers, m, seq)
        rows.append(row)
        LAST_METRICS[name] = summary
    if print_csv:
        for r in rows:
            print(f"obs_audit,{r['config']},kind={r['kind']},b={r['b']},"
                  f"m={r['m']},sim_n={r['sim_n']},real_n={r['real_n']},"
                  f"census={r['census']},"
                  f"time_scale={r['time_scale']:.4g},"
                  f"skew_F={r['skew_F']:.3f},skew_B={r['skew_B']:.3f},"
                  f"max_order_div={r['max_order_div']:.3f},"
                  f"bubble={r['bubble_pct']:.2f},"
                  f"peak_hbm_mib={r['peak_hbm_mib']:.2f},"
                  f"chan_occ={r['chan_occ']:.3f}")
    return rows


if __name__ == "__main__":
    main()
