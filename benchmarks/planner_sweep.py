"""Planner sweep: run the schedule auto-planner over every registered
config (the paper's two models + the 11 assigned architectures) and
print the winning plan per attention arm.

Columns: config, arm, kind, v, b, m, cap, peak_GiB, mfu, n_feasible,
n_rejected (break-even), n_oom — or best=none when nothing fits.

``--smoke`` (via benchmarks/run.py) plans only the two smallest configs
at a toy shape, exercising the full enumerate -> prune -> rank path in
seconds on CPU.
"""
from __future__ import annotations

from repro.configs import get_config, list_configs
from repro.core.notation import A100_HBM_BYTES, from_model
from repro.planner import SearchSpace, plan_config, recommend
from repro.planner.rank import arms_of


def _pow2_at_most(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def plan_one(name: str, smoke: bool = False):
    cfg = get_config(name)
    if smoke:
        p = min(4, _pow2_at_most(cfg.num_layers))
        n = from_model(cfg, b=1, s=512, B=32, p=p, t=1)
        hbm = 16 * 1024**3
        search = SearchSpace(vs=(2,))
    else:
        p = min(8, _pow2_at_most(cfg.num_layers))
        n = from_model(cfg, b=1, s=2048, B=128, p=p, t=4)
        hbm = A100_HBM_BYTES
        search = SearchSpace()
    return n, plan_config(n, cfg, hbm, search=search)


def smallest_configs(k: int = 2):
    return sorted(list_configs(),
                  key=lambda c: get_config(c).param_count())[:k]


def main(print_csv=True, smoke=False):
    names = smallest_configs(2) if smoke else list_configs()
    rows = []
    for name in names:
        n, ranked = plan_one(name, smoke)
        counts = {
            "feasible": sum(1 for p in ranked if p.ok),
            "rejected": sum(1 for p in ranked if p.verdict == "reject"),
            "oom": sum(1 for p in ranked if p.verdict == "infeasible"),
        }
        for arm in arms_of(ranked) + [None]:
            best = recommend(ranked, arm)
            tag = arm or "overall"
            rows.append((name, tag, best, counts))
            if not print_csv:
                continue
            if best is None:
                print(f"planner_sweep,{name},{tag},best=none,"
                      f"oom={counts['oom']}")
            else:
                c = best.cand
                print(f"planner_sweep,{name},{tag},kind={c.kind},v={c.v},"
                      f"b={c.b},m={c.m},"
                      f"cap={c.cap if c.cap is not None else 'def'},"
                      f"peak_gib={best.feas.peak_gib:.1f},"
                      f"mfu={100 * best.mfu:.1f},"
                      f"feasible={counts['feasible']},"
                      f"rejected={counts['rejected']},oom={counts['oom']}")
    return rows


if __name__ == "__main__":
    main()
