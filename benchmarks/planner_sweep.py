"""Planner sweep: run the schedule auto-planner over every registered
config (the paper's two models + the 11 assigned architectures) and
print the winning plan per attention arm.

Columns: config, arm, kind, v, b, m, cap, peak_GiB, mfu, plan_time_s,
n_enumerated, n_simulated, n_feasible, n_rejected (break-even),
n_pruned (branch-and-bound), n_oom — or best=none when nothing fits.
The per-config wall time and search counters also land in the module's
``LAST_METRICS`` (benchmarks/run.py copies it into the JSON report), so
CI runs leave a planner-speed trajectory. ``exhaustive=True`` disables
the branch-and-bound pruning — the before/after baseline; smoke runs
time it automatically (``plan_time_s_exhaustive`` in the metrics), so
``BENCH_smoke.json`` records both sides of the speedup.

``--smoke`` (via benchmarks/run.py) plans only the two smallest configs
at a toy shape, exercising the full enumerate -> prune -> rank path in
seconds on CPU.
"""
from __future__ import annotations

import time

from repro.configs import get_config, list_configs
from repro.core import plan as plan_mod
from repro.core.notation import A100_HBM_BYTES, from_model
from repro.planner import SearchSpace, plan_config, recommend
from repro.planner.rank import arms_of

#: Search statistics of the last ``main`` run: per-config plan_time_s +
#: verdict counts, and the sweep totals (benchmarks/run.py JSON report).
LAST_METRICS = None


def _pow2_at_most(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def plan_one(name: str, smoke: bool = False, exhaustive: bool = False):
    cfg = get_config(name)
    if smoke:
        p = min(4, _pow2_at_most(cfg.num_layers))
        n = from_model(cfg, b=1, s=512, B=32, p=p, t=1)
        hbm = 16 * 1024**3
        search = SearchSpace(vs=(2,))
    else:
        p = min(8, _pow2_at_most(cfg.num_layers))
        n = from_model(cfg, b=1, s=2048, B=128, p=p, t=4)
        hbm = A100_HBM_BYTES
        search = SearchSpace()
    return n, plan_config(n, cfg, hbm, search=search,
                          exhaustive=exhaustive)


def smallest_configs(k: int = 2):
    return sorted(list_configs(),
                  key=lambda c: get_config(c).param_count())[:k]


def main(print_csv=True, smoke=False, exhaustive=False):
    global LAST_METRICS
    names = smallest_configs(2) if smoke else list_configs()
    rows = []
    per_config = []
    for name in names:
        t0 = time.perf_counter()
        n, ranked = plan_one(name, smoke, exhaustive)
        plan_time = time.perf_counter() - t0
        counts = {
            "enumerated": len(ranked),
            "simulated": sum(1 for p in ranked if p.makespan > 0),
            "feasible": sum(1 for p in ranked if p.ok),
            "rejected": sum(1 for p in ranked if p.verdict == "reject"),
            "pruned": sum(1 for p in ranked if p.verdict == "pruned"),
            "oom": sum(1 for p in ranked if p.verdict == "infeasible"),
        }
        per_config.append({"config": name, "plan_time_s": round(plan_time, 4),
                           **counts})
        for arm in arms_of(ranked) + [None]:
            best = recommend(ranked, arm)
            tag = arm or "overall"
            rows.append((name, tag, best, counts))
            if not print_csv:
                continue
            if best is None:
                print(f"planner_sweep,{name},{tag},best=none,"
                      f"oom={counts['oom']}")
            else:
                c = best.cand
                print(f"planner_sweep,{name},{tag},kind={c.kind},v={c.v},"
                      f"b={c.b},m={c.m},"
                      f"cap={c.cap if c.cap is not None else 'def'},"
                      f"peak_gib={best.feas.peak_gib:.1f},"
                      f"mfu={100 * best.mfu:.1f},"
                      f"plan_time_s={plan_time:.3f},"
                      f"enumerated={counts['enumerated']},"
                      f"simulated={counts['simulated']},"
                      f"feasible={counts['feasible']},"
                      f"rejected={counts['rejected']},"
                      f"pruned={counts['pruned']},oom={counts['oom']}")
    LAST_METRICS = {
        "exhaustive": exhaustive,
        "plan_time_s": round(sum(c["plan_time_s"] for c in per_config), 4),
        "enumerated": sum(c["enumerated"] for c in per_config),
        "simulated": sum(c["simulated"] for c in per_config),
        "pruned": sum(c["pruned"] for c in per_config),
        "configs": per_config,
    }
    if smoke and not exhaustive:
        # Before/after datapoint for the JSON report: time the same smoke
        # configs with pruning disabled. Cold-start both sides — the
        # pruned pass above began with an empty compile cache too.
        plan_mod.compile_plan.cache_clear()
        t0 = time.perf_counter()
        for name in names:
            plan_one(name, smoke, exhaustive=True)
        LAST_METRICS["plan_time_s_exhaustive"] = round(
            time.perf_counter() - t0, 4)
    return rows


if __name__ == "__main__":
    main()
