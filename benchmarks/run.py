"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--smoke] [--only NAME]
                                            [--json PATH]

Prints ``name,...`` CSV rows:
  table3             paper Table 3 (MFU, all 10 experiments, +TPU variant)
  table5             paper §4 estimation validation (eq. 4 pairs)
  memory_balance     paper Fig. 1 / A100 fit analysis (1F1B vs BPipe)
  interleaved_sweep  beyond-paper: interleaved 1F1B/BPipe bubble-memory
  residency_sweep    activation-residency contest: swap/offload/recompute
  estimator_accuracy eq.4 vs discrete-event simulator across a grid
  kernel_bench       Pallas kernels + §3.2 fusion-count analysis
  roofline           per-(arch x shape) roofline terms from the dry-run
  planner_sweep      schedule auto-planner over every registered config
  longcontext_sweep  sequence-sliced planner verdicts at 32k/128k
  vocab_sweep        vocab-parallel verdicts on 151k- vs 32k-vocab configs
  obs_audit          sim-vs-real divergence audit on the paper shapes

``--smoke`` runs every benchmark on tiny CPU-only shapes (subset grids,
the two smallest configs for the planner) so the whole suite doubles as
an offline regression check — scripts/check.sh wires it in. Smoke runs
also write a machine-readable ``BENCH_smoke.json`` (per-benchmark status,
wall time, and the CSV rows) so CI runs leave comparable perf-trajectory
data points; ``--json PATH`` overrides the destination (or enables it
for non-smoke runs).
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description="run reproduction benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configs, CPU-only, seconds not minutes")
    ap.add_argument("--only", default="",
                    help="run a single benchmark by name")
    ap.add_argument("--json", default="",
                    help="write per-benchmark results as JSON here "
                         "(default: BENCH_smoke.json when --smoke)")
    args = ap.parse_args(argv)
    json_path = args.json or ("BENCH_smoke.json" if args.smoke else "")

    from benchmarks import (estimator_accuracy, interleaved_sweep,
                            kernel_bench, longcontext_sweep, memory_balance,
                            obs_audit, planner_sweep, residency_sweep,
                            roofline_table, table3, table5, vocab_sweep)
    mods = {
        "table3": table3,
        "table5": table5,
        "memory_balance": memory_balance,
        "interleaved_sweep": interleaved_sweep,
        "residency_sweep": residency_sweep,
        "estimator_accuracy": estimator_accuracy,
        "kernel_bench": kernel_bench,
        "roofline": roofline_table,
        "planner_sweep": planner_sweep,
        "longcontext_sweep": longcontext_sweep,
        "vocab_sweep": vocab_sweep,
        "obs_audit": obs_audit,
    }
    if args.only:
        if args.only not in mods:
            sys.exit(f"unknown benchmark {args.only!r}; "
                     f"known: {sorted(mods)}")
        mods = {args.only: mods[args.only]}
    ok = True
    results = []
    for name, mod in mods.items():
        # Capture the benchmark's CSV rows while still printing them, so
        # the JSON report carries the same machine-readable data.
        buf = io.StringIO()
        t0 = time.perf_counter()
        status = "ok"
        try:
            with contextlib.redirect_stdout(buf):
                mod.main(smoke=args.smoke)
        except Exception:  # noqa: BLE001
            ok = False
            status = "fail"
            print(f"BENCH_FAIL,{mod.__name__}", file=sys.stderr)
            traceback.print_exc()
        out = buf.getvalue()
        sys.stdout.write(out)
        entry = {
            "benchmark": name, "status": status,
            "seconds": round(time.perf_counter() - t0, 4),
            "rows": [ln for ln in out.splitlines() if ln.strip()],
        }
        # Benchmarks that fold an observability summary (bubble%, peak
        # HBM, channel occupancy — see benchmarks/obs_audit.py) publish
        # it as LAST_METRICS; copy it into the JSON report.
        metrics = getattr(mod, "LAST_METRICS", None)
        if metrics is not None:
            entry["metrics"] = metrics
        results.append(entry)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"smoke": args.smoke, "results": results}, f, indent=1)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
