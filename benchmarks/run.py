"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,...`` CSV rows:
  table3             paper Table 3 (MFU, all 10 experiments, +TPU variant)
  table5             paper §4 estimation validation (eq. 4 pairs)
  memory_balance     paper Fig. 1 / A100 fit analysis (1F1B vs BPipe)
  interleaved_sweep  beyond-paper: interleaved 1F1B/BPipe bubble-memory
  estimator_accuracy eq.4 vs discrete-event simulator across a grid
  kernel_bench       Pallas kernels + §3.2 fusion-count analysis
  roofline           per-(arch x shape) roofline terms from the dry-run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (estimator_accuracy, interleaved_sweep,
                            kernel_bench, memory_balance, roofline_table,
                            table3, table5)
    ok = True
    for mod in (table3, table5, memory_balance, interleaved_sweep,
                estimator_accuracy, kernel_bench, roofline_table):
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            ok = False
            print(f"BENCH_FAIL,{mod.__name__}", file=sys.stderr)
            traceback.print_exc()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
