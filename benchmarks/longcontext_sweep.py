"""Long-context planner sweep: does sequence slicing change the verdict?

For each ``configs.longcontext`` case (the paper's two models at 32k and
128k) the planner runs twice over the SAME candidate axes — once
restricted to the unsliced classic (seq_chunks=1, exactly today's
engine) and once with the case's chunk ladder open — and the table shows
what slicing buys: the recommended plan, its simulated makespan/MFU, the
per-stage peak bytes, and whether the recommendation itself moved
(``verdict_changed``). The paper-condition verdicts (s=2048, Table 3)
are untouched by design: the default ``SearchSpace`` stays unsliced;
this sweep is where the c > 1 arm competes.

Peak bytes at c > 1 trade the 2sbh/t boundary stash (divided by c) for
retained KV (4sbh/t per layer, c-1 slices' worth at the worst slice) —
see ``memory_model.sliced_unit_bytes`` and docs/longcontext.md for when
that wins.

Row order is pinned (plain list, declared case order) so
``BENCH_smoke.json`` diffs stay stable.

Columns: case, arm, makespan, mfu, peak_gib, plan | unsliced twin
columns | verdict_changed, peak_drop_pct.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs import get_config
from repro.configs.longcontext import LONG_CONTEXT, LongContextCase
from repro.core.notation import A100_HBM_BYTES, Notation
from repro.planner import SearchSpace, cost_model_for, plan_config, recommend

#: HBM budgets per case: 80 GiB (A100) everywhere — the whole point is
#: seeing which shapes ONLY fit (or only rank well) once sliced.
HBM = A100_HBM_BYTES

SMOKE_CASE = LongContextCase("smoke-32k", "smoke", 32_768, 8, p=4, t=1,
                             seq_chunkses=(1, 2, 4))
SMOKE_N = Notation(a=4, b=1, h=256, l=16, s=32_768, v=512, B=8, p=4, t=1)
SMOKE_HBM = 6 * 1024**3


def _cells(prefix: str, rp) -> str:
    if rp is None:
        return (f"{prefix}makespan=-,{prefix}mfu=-,{prefix}peak_gib=-,"
                f"{prefix}plan=infeasible")
    return (f"{prefix}makespan={rp.makespan:.4g},"
            f"{prefix}mfu={100 * rp.mfu:.1f},"
            f"{prefix}peak_gib={rp.feas.peak_gib:.2f},"
            f"{prefix}plan={rp.cand.label().replace(' ', '/')}")


def sweep_case(case: LongContextCase, n: Notation, cfg, hbm: float,
               print_csv: bool = True) -> List[dict]:
    cost = cost_model_for(cfg)
    base = plan_config(n, cfg, hbm, cost=cost,
                       search=SearchSpace(seq_chunkses=(1,)))
    sliced = plan_config(n, cfg, hbm, cost=cost,
                         search=SearchSpace(
                             seq_chunkses=case.seq_chunkses))
    rows = []
    for att in ("recompute", "flash"):
        b_rp, s_rp = recommend(base, att), recommend(sliced, att)
        changed = ((b_rp is None) != (s_rp is None)
                   or (b_rp is not None and s_rp is not None
                       and b_rp.cand != s_rp.cand))
        drop = 0.0
        if b_rp is not None and s_rp is not None and b_rp.feas.peak_bytes:
            drop = 100.0 * (1.0 - s_rp.feas.peak_bytes
                            / b_rp.feas.peak_bytes)
        rows.append({"case": case.name, "attention": att,
                     "base": b_rp, "sliced": s_rp,
                     "verdict_changed": changed, "peak_drop_pct": drop})
        if print_csv:
            print(f"longcontext_sweep,{case.name},{att},"
                  + _cells("", s_rp) + "," + _cells("base_", b_rp)
                  + f",verdict_changed={int(changed)}"
                  + f",peak_drop_pct={drop:.1f}")
    return rows


def main(print_csv=True, smoke=False):
    rows = []
    if smoke:
        cfg = None   # analytic cost model on a toy Notation
        rows += sweep_case(SMOKE_CASE, SMOKE_N, cfg, SMOKE_HBM, print_csv)
        return rows
    for name in sorted(LONG_CONTEXT):
        case = LONG_CONTEXT[name]
        cfg = get_config(case.model)
        n = case.notation(cfg)
        rows += sweep_case(case, n, cfg, HBM, print_csv)
    return rows


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
