"""Paper Fig. 1 story: per-stage peak memory under 1F1B vs BPipe, and the
A100-80G fit decisions behind every Table 3 row.

Columns: model, attention, b, schedule, stage memories (GiB), fits.
"""
from __future__ import annotations

from repro.core import memory_model as MM
from repro.core.notation import A100_HBM_BYTES, GPT3_96B, LLAMA_65B
from repro.core.plan import ScheduleSpec

CASES = [
    ("gpt3-96b", GPT3_96B, "recompute", (1, 2)),
    ("llama-65b", LLAMA_65B, "none", (1,)),
    ("llama-65b", LLAMA_65B, "recompute", (2, 4)),
    ("llama-65b", LLAMA_65B, "flash", (1, 2, 4)),
]


def main(print_csv=True, smoke=False):
    rows = []
    for name, n, att, bs in (CASES[:1] if smoke else CASES):
        for b in bs:
            for kind in ("1f1b", "bpipe"):
                # unbound spec template: the memory model binds m = B/b
                spec = ScheduleSpec(kind, n.p)
                mems = MM.per_stage_memory(n.replace(b=b), att, spec)
                total = [m.total / 2**30 for m in mems]
                fits = MM.fits(n.replace(b=b), att, spec, A100_HBM_BYTES)
                rows.append((name, att, b, kind, total, fits))
                if print_csv:
                    stages = "/".join(f"{t:.0f}" for t in total)
                    print(f"memory_balance,{name},{att},b={b},{kind},"
                          f"stages_GiB={stages},max={max(total):.1f},"
                          f"fits_a100={int(fits)}")
    return rows


if __name__ == "__main__":
    main()
