"""BPipe planning: pairing, layout (paper Fig. 2), TPU hop distances."""
from hypothesis import given, settings, strategies as st

from repro.core import bpipe as BP
from repro.core import schedule as S


@given(st.integers(2, 32))
@settings(max_examples=40, deadline=None)
def test_layout_pairs_adjacent(p):
    plan = BP.plan(p, 4 * p)
    layout = plan.stage_to_device
    assert sorted(layout) == list(range(p))
    hops = BP.hop_distance(plan)
    assert all(h == 1 for h in hops.values()), hops
    if p % 2 == 0:
        assert BP.pairs_within_node(plan, 2)  # paper Fig.2, node size 2
    if p == 16:
        assert BP.pairs_within_node(plan, 8)  # paper's 2x8-GPU nodes


@given(st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_pairing_is_involution(p):
    plan = BP.plan(p, 2 * p)
    partner = plan.partner
    for a, b in plan.pairs:
        assert partner[a] == b and partner[b] == a
        assert a + b == p - 1


def test_hop_distance_on_larger_device_ring():
    """Regression: hop distances must use the device-ring extent, not p.

    4 stages on an 8-device ring, pairs placed at the ring's wrap seam:
    the old p-sized default computed min(7, 4-7) = -3 for the (0, 3)
    pair. On the 8-ring both pairs are 1 hop apart."""
    plan = BP.plan(4, 16, stage_to_device=(0, 3, 4, 7))
    assert BP.ring_extent(plan) == 8
    hops = BP.hop_distance(plan)
    assert hops == {(0, 3): 1, (1, 2): 1}, hops
    assert all(h >= 0 for h in hops.values())
    # an explicit ring_size still wins
    assert BP.hop_distance(plan, ring_size=16) == {(0, 3): 7, (1, 2): 1}


@given(st.integers(2, 16), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_hop_distance_nonnegative_on_any_ring(p, stride):
    """Stages strided across a mesh axis stride x larger than p: every
    pair distance is a valid ring distance (0 <= d <= ring//2)."""
    layout = tuple(i * stride for i in BP.pair_adjacent_layout(p))
    plan = BP.plan(p, 4 * p, stage_to_device=layout)
    ring = BP.ring_extent(plan)
    for (a, b), d in BP.hop_distance(plan).items():
        assert 0 <= d <= ring // 2, (p, stride, a, b, d)


def test_plan_matches_schedule_evictions():
    plan = BP.plan(8, 64)
    assert plan.cap == S.bpipe_cap(8)
    assert plan.evictions == tuple(S.num_evictions(8, 64, i) for i in range(8))


def test_fig2_sixteen_way():
    """Paper Fig. 2: 16-way PP on two 8-GPU nodes, pairs node-local."""
    plan = BP.plan(16, 128)
    assert BP.pairs_within_node(plan, 8)
    # evictors are exactly stages 0..(p-cap-1+1)
    for i, ne in enumerate(plan.evictions):
        if min(16 - i, 128) > plan.cap:
            assert ne > 0, i
        else:
            assert ne == 0, i
