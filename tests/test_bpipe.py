"""BPipe planning: pairing, layout (paper Fig. 2), TPU hop distances."""
from hypothesis import given, settings, strategies as st

from repro.core import bpipe as BP
from repro.core import schedule as S


@given(st.integers(2, 32))
@settings(max_examples=40, deadline=None)
def test_layout_pairs_adjacent(p):
    plan = BP.plan(p, 4 * p)
    layout = plan.stage_to_device
    assert sorted(layout) == list(range(p))
    hops = BP.hop_distance(plan)
    assert all(h == 1 for h in hops.values()), hops
    if p % 2 == 0:
        assert BP.pairs_within_node(plan, 2)  # paper Fig.2, node size 2
    if p == 16:
        assert BP.pairs_within_node(plan, 8)  # paper's 2x8-GPU nodes


@given(st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_pairing_is_involution(p):
    plan = BP.plan(p, 2 * p)
    partner = plan.partner
    for a, b in plan.pairs:
        assert partner[a] == b and partner[b] == a
        assert a + b == p - 1


def test_plan_matches_schedule_evictions():
    plan = BP.plan(8, 64)
    assert plan.cap == S.bpipe_cap(8)
    assert plan.evictions == tuple(S.num_evictions(8, 64, i) for i in range(8))


def test_fig2_sixteen_way():
    """Paper Fig. 2: 16-way PP on two 8-GPU nodes, pairs node-local."""
    plan = BP.plan(16, 128)
    assert BP.pairs_within_node(plan, 8)
    # evictors are exactly stages 0..(p-cap-1+1)
    for i, ne in enumerate(plan.evictions):
        if min(16 - i, 128) > plan.cap:
            assert ne > 0, i
        else:
            assert ne == 0, i
