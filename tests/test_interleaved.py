"""Interleaved (virtual-chunk) 1F1B and its BPipe composition —
beyond-paper schedule extension (schedule-level; the executor/simulator
interpret non-interleaved streams)."""
from hypothesis import given, settings, strategies as st

from repro.core import schedule as S

pmv = st.tuples(st.integers(2, 12), st.integers(1, 4), st.integers(2, 4)).map(
    lambda t: (t[0], t[0] * t[1], t[2]))  # m multiple of p


@given(pmv)
@settings(max_examples=40, deadline=None)
def test_interleaved_well_formed(t):
    p, m, v = t
    for i in range(p):
        stream = S.one_f_one_b_interleaved(p, m, i, v)
        fs = [(x.chunk, x.mb) for x in stream if x.op == S.F]
        bs = [(x.chunk, x.mb) for x in stream if x.op == S.B]
        assert len(fs) == m * v and sorted(fs) == sorted(set(fs))
        assert sorted(bs) == sorted(fs)
        # every unit's backward comes after its forward
        seen = set()
        for x in stream:
            if x.op == S.F:
                seen.add((x.chunk, x.mb))
            elif x.op == S.B:
                assert (x.chunk, x.mb) in seen


@given(pmv)
@settings(max_examples=40, deadline=None)
def test_interleaved_peak_formula(t):
    p, m, v = t
    for i in range(p):
        held, peak = set(), 0
        for x in S.one_f_one_b_interleaved(p, m, i, v):
            if x.op == S.F:
                held.add((x.chunk, x.mb))
            elif x.op == S.B:
                held.discard((x.chunk, x.mb))
            peak = max(peak, len(held))
        assert peak <= S.interleaved_peak(p, m, i, v)


@given(pmv)
@settings(max_examples=30, deadline=None)
def test_bpipe_interleaved_cap_and_balance(t):
    p, m, v = t
    cap = S.bpipe_interleaved_cap(p, v)
    streams = {i: S.bpipe_interleaved(p, m, i, v) for i in range(p)}
    # local + accepted-foreign accounting via the merged trace
    traces = S.stash_trace(streams, p)
    peaks = {i: (max(tr) if tr else 0) for i, tr in traces.items()}
    assert max(peaks.values()) <= cap, (p, m, v, peaks, cap)
    plain = {}
    for i in range(p):
        held, pk = set(), 0
        for x in S.one_f_one_b_interleaved(p, m, i, v):
            if x.op == S.F:
                held.add((x.chunk, x.mb))
            elif x.op == S.B:
                held.discard((x.chunk, x.mb))
            pk = max(pk, len(held))
        plain[i] = pk
    spread_plain = max(plain.values()) - min(plain.values())
    spread_bp = max(peaks.values()) - min(peaks.values())
    assert spread_bp <= spread_plain


def test_interleaved_vs_plain_memory_tradeoff():
    """v chunks shrink the bubble ~v-fold but raise the stage-0 stash:
    units x (1/v layers) => layer-equivalents grow from p to
    ~2(p-1)/v + (v-1)p/v + 1/v."""
    p, m = 8, 32
    plain_peak = S.peak_stash("1f1b", p, m)[0]            # p units of 1
    inter_units = S.interleaved_peak(p, m, 0, v=2)
    layer_equiv = inter_units / 2
    assert plain_peak == 8 and inter_units == 23
    assert layer_equiv > plain_peak  # interleaving costs memory...
    # ...which is exactly the regime where BPipe's balancing pays more:
    assert S.bpipe_interleaved_cap(p, 2) < inter_units