"""Minimal deterministic stand-in for ``hypothesis`` (offline fallback).

The tier-1 suite property-tests schedules with hypothesis; this container
has no network and no hypothesis wheel, so ``tests/conftest.py`` installs
this module as ``sys.modules["hypothesis"]`` when the real package is
absent.  It implements exactly the API surface the suite uses:

    from hypothesis import given, settings, strategies as st
    st.integers(lo, hi)  st.floats(lo, hi)  st.sampled_from(seq)
    st.tuples(*strats)   st.lists(strat, min_size=, max_size=)
    strategy.map(fn)

Sampling is seeded and deterministic: each ``@given`` test runs its
strategies' boundary combinations first (lo/hi corners, first/last
choices) and then fills up to ``max_examples`` with draws from a fixed
PRNG, so failures reproduce run-to-run.  This is a *fallback*, not a
replacement — no shrinking, no example database; when hypothesis is
installed the real thing is used (see conftest).
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
import sys
import types
from typing import Any, Callable, List, Sequence

_SEED = 0xB17E5EED
_DEFAULT_MAX_EXAMPLES = 25
_MAX_BOUNDARY_COMBOS = 8


class SearchStrategy:
    """Base strategy: deterministic boundary examples + seeded draws."""

    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def boundary(self) -> List[Any]:
        return []

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)


class _Mapped(SearchStrategy):
    def __init__(self, base: SearchStrategy, fn: Callable):
        self.base, self.fn = base, fn

    def example(self, rng):
        return self.fn(self.base.example(rng))

    def boundary(self):
        return [self.fn(x) for x in self.base.boundary()]


class _Integers(SearchStrategy):
    def __init__(self, lo: int, hi: int):
        assert lo <= hi, (lo, hi)
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return rng.randint(self.lo, self.hi)

    def boundary(self):
        out = [self.lo, self.hi]
        if self.hi - self.lo > 1:
            out.append((self.lo + self.hi) // 2)
        return list(dict.fromkeys(out))


class _Floats(SearchStrategy):
    def __init__(self, lo: float, hi: float):
        assert lo <= hi, (lo, hi)
        self.lo, self.hi = lo, hi

    def example(self, rng):
        return rng.uniform(self.lo, self.hi)

    def boundary(self):
        return list(dict.fromkeys([self.lo, self.hi]))


class _SampledFrom(SearchStrategy):
    def __init__(self, elems: Sequence[Any]):
        self.elems = list(elems)
        assert self.elems

    def example(self, rng):
        return rng.choice(self.elems)

    def boundary(self):
        out = [self.elems[0], self.elems[-1]]
        return out[:1] if out[0] == out[-1] else out


class _Tuples(SearchStrategy):
    def __init__(self, *strats: SearchStrategy):
        self.strats = strats

    def example(self, rng):
        return tuple(s.example(rng) for s in self.strats)

    def boundary(self):
        combos = itertools.product(*(s.boundary() or [s.example(random.Random(_SEED))]
                                     for s in self.strats))
        return [tuple(c) for c in itertools.islice(combos, _MAX_BOUNDARY_COMBOS)]


class _Lists(SearchStrategy):
    def __init__(self, elem: SearchStrategy, min_size: int = 0,
                 max_size: int = 10):
        self.elem, self.min_size, self.max_size = elem, min_size, max_size

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]

    def boundary(self):
        rng = random.Random(_SEED)
        out = [[self.elem.example(rng) for _ in range(self.min_size)],
               [self.elem.example(rng) for _ in range(self.max_size)]]
        return [x for x in out if len(x) >= self.min_size][:2]


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return _Floats(min_value, max_value)


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    return _SampledFrom(elements)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return _Tuples(*strats)


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10, **_kw) -> SearchStrategy:
    return _Lists(elements, min_size=min_size, max_size=max_size)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_kw) -> Callable:
    """Record max_examples on the (possibly already-wrapped) test fn."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats: SearchStrategy) -> Callable:
    """Run the test over boundary combos + seeded draws, deterministically."""
    assert strats and all(isinstance(s, SearchStrategy) for s in strats), strats

    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            max_examples = getattr(wrapper, "_stub_max_examples",
                                   getattr(fn, "_stub_max_examples",
                                           _DEFAULT_MAX_EXAMPLES))
            examples: List[tuple] = []
            bnds = [s.boundary() for s in strats]
            if all(bnds):
                examples.extend(itertools.islice(
                    itertools.product(*bnds), _MAX_BOUNDARY_COMBOS))
            rng = random.Random(_SEED)
            while len(examples) < max_examples:
                examples.append(tuple(s.example(rng) for s in strats))
            for ex in examples[:max_examples]:
                try:
                    fn(*ex)
                except Exception as e:  # noqa: BLE001 — annotate the example
                    raise AssertionError(
                        f"falsifying example (hypothesis stub): "
                        f"{fn.__name__}{ex!r}") from e
        # pytest introspects the signature for fixture names; the wrapper
        # supplies every argument itself, so present a 0-arg signature and
        # drop __wrapped__ (inspect.signature follows it otherwise).
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper
    return deco


# Expose a ``hypothesis.strategies``-shaped submodule so both
# ``from hypothesis import strategies as st`` and
# ``import hypothesis.strategies`` resolve against this stub.
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.tuples = tuples
strategies.lists = lists
strategies.SearchStrategy = SearchStrategy


def install() -> None:
    """Register this module as ``hypothesis`` (idempotent)."""
    mod = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", mod)
    sys.modules.setdefault("hypothesis.strategies", strategies)
