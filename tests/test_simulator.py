"""Discrete-event simulator vs the paper's closed forms."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import simulator as SIM
from repro.core.estimator import bubble_factor
from repro.core.notation import Notation


@given(st.integers(2, 12), st.integers(1, 8), st.floats(0.5, 3.0))
@settings(max_examples=40, deadline=None)
def test_1f1b_matches_eq2_idealization(p, mm, tf):
    m = p * mm
    c = SIM.SimConfig(p=p, m=m, Tf=tf, Tb=2 * tf, kind="1f1b")
    res = SIM.simulate(c)
    assert res.makespan == pytest.approx(SIM.ideal_makespan(c), rel=1e-9)
    # bubble fraction = (p-1)/(m+p-1)
    assert res.bubble_fraction == pytest.approx((p - 1) / (m + p - 1), rel=1e-6)


@given(st.integers(2, 12), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_bpipe_free_with_infinite_bandwidth(p, mm):
    m = p * mm
    base = SIM.simulate(SIM.SimConfig(p=p, m=m, Tf=1, Tb=2, kind="1f1b"))
    bp = SIM.simulate(SIM.SimConfig(p=p, m=m, Tf=1, Tb=2, kind="bpipe"))
    assert bp.makespan == pytest.approx(base.makespan)
    assert bp.load_stall == 0.0


def test_bpipe_overhead_with_slow_link():
    base = SIM.simulate(SIM.SimConfig(p=8, m=64, Tf=1, Tb=2, kind="1f1b"))
    slow = SIM.simulate(SIM.SimConfig(p=8, m=64, Tf=1, Tb=2, kind="bpipe",
                                      evict_bytes=10e9, pair_bw=1e9))
    assert slow.makespan > base.makespan
    assert slow.load_stall > 0


def test_bpipe_overlap_threshold():
    """Transfers stay hidden while the pair link keeps up. Steady state
    moves TWO stashes (evict+load) per F+B window, so the threshold is
    t_move <= (Tf+Tb)/2 — a sharper bound than the paper's qualitative
    'communication can overlap' claim."""
    base = SIM.simulate(SIM.SimConfig(p=8, m=64, Tf=1, Tb=2, kind="1f1b"))
    for t_move in (0.5, 1.0, 1.4):
        r = SIM.simulate(SIM.SimConfig(p=8, m=64, Tf=1, Tb=2, kind="bpipe",
                                       evict_bytes=t_move, pair_bw=1.0))
        assert r.makespan == pytest.approx(base.makespan), t_move
    # past the threshold the link saturates and backwards stall
    r = SIM.simulate(SIM.SimConfig(p=8, m=64, Tf=1, Tb=2, kind="bpipe",
                                   evict_bytes=2.9, pair_bw=1.0))
    assert r.makespan > base.makespan


def test_gpipe_same_time_different_memory():
    g = SIM.simulate(SIM.SimConfig(p=4, m=16, Tf=1, Tb=2, kind="gpipe"))
    f = SIM.simulate(SIM.SimConfig(p=4, m=16, Tf=1, Tb=2, kind="1f1b"))
    assert g.makespan == pytest.approx(f.makespan)


def test_bubble_factor_matches_sim():
    n = Notation(a=8, b=2, h=512, l=8, s=128, v=1000, B=32, p=4, t=1)
    c = SIM.SimConfig(p=n.p, m=n.num_micro, Tf=1, Tb=2, kind="1f1b")
    res = SIM.simulate(c)
    ideal_compute = n.num_micro * (c.Tf + c.Tb)
    assert res.makespan / ideal_compute == pytest.approx(bubble_factor(n))


def test_mfu_from_sim():
    c = SIM.SimConfig(p=8, m=128, Tf=1.0, Tb=2.0, kind="1f1b")
    res = SIM.simulate(c)
    # if model_flops == busy_time * peak * p * t, MFU == compute efficiency
    P, t = 100.0, 1
    model_flops = 128 * 3.0 * 8 * P  # m microbatches x (Tf+Tb) x p stages x P
    mfu = SIM.mfu_from_sim(res, model_flops, 8, t, P)
    assert mfu == pytest.approx(128 * 3 / res.makespan, rel=1e-6)
    assert mfu == pytest.approx(1 - res.bubble_fraction, rel=1e-6)
