"""The schedule auto-planner: feasibility of everything it emits,
optimality against a brute-force simulator sweep, the paper's Table 3
win/loss verdicts from first principles, and the executor-trace
calibration round trip."""
import dataclasses

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import estimator as E
from repro.core import memory_model as MM
from repro.core import schedule as S
from repro.core import simulator as SIM
from repro.core.notation import A100_HBM_BYTES, GPT3_96B, LLAMA_65B, Notation
from repro.planner import (AnalyticCostModel, SearchSpace, Table5CostModel,
                           calibrate, plan_config, recommend, report)
from repro.planner import rank as R
from repro.planner import space as SP


def _n(p, B, b=1):
    return Notation(a=4, b=b, h=256, l=16, s=128, v=512, B=B, p=p, t=1)


def _small_ranked(p, B):
    n = _n(p, B)
    cost = AnalyticCostModel()
    # budget: the b=1 1F1B peak with a little headroom, so larger micro
    # batches (and fatter interleaved stashes) genuinely prune
    hbm = 1.2 * MM.max_stage_bytes(n, "recompute", "1f1b")
    cands = SP.enumerate_candidates(n, SearchSpace(vs=(2,)))
    return n, hbm, cost, R.rank(n, cands, cost, hbm, workspace=0.0)


# ---------------------------------------------------------------------------
# Property: everything the planner calls feasible IS feasible
# ---------------------------------------------------------------------------
@given(st.integers(2, 4), st.sampled_from([8, 16]))
@settings(max_examples=8, deadline=None)
def test_planner_emits_only_feasible_plans(p, B):
    n, hbm, _, ranked = _small_ranked(p, B)
    assert ranked, "search space empty"
    assert recommend(ranked) is not None
    for rp in ranked:
        c = rp.cand
        if rp.verdict == "pruned":
            # branch-and-bound discard: never simulated, no claims made
            assert rp.makespan == 0.0 and rp.mfu == 0.0
            continue
        if not rp.feas.ok:
            assert rp.verdict == "infeasible"
            continue
        # structural validity
        assert B % c.b == 0 and c.m == B // c.b
        if c.kind in S.INTERLEAVED:
            assert c.v >= 2 and c.m % p == 0
        # and the memory model agrees, cap-, v-chunk- and residency-aware
        peak = MM.max_stage_bytes(n.replace(b=c.b), c.attention, c.spec(p))
        assert peak <= hbm, (c, peak, hbm)
        assert peak == pytest.approx(rp.feas.peak_bytes)


# ---------------------------------------------------------------------------
# Property: the ranked-best plan never loses to a brute-force sweep
# ---------------------------------------------------------------------------
@given(st.integers(2, 4), st.sampled_from([8, 16]))
@settings(max_examples=6, deadline=None)
def test_best_plan_beats_bruteforce_sim_sweep(p, B):
    n, hbm, cost, ranked = _small_ranked(p, B)
    survivors = [rp for rp in ranked if rp.ok]
    best = recommend(ranked)
    assert best is rp_max_mfu(survivors)
    for rp in survivors:
        c = rp.cand
        # brute force: re-simulate every survivor independently
        nb = n.replace(b=c.b)
        T = cost.stage_T(nb, c.attention)
        spec = c.spec(p)
        res = SIM.simulate(SIM.SimConfig(
            spec=spec, Tf=T / 3.0, Tb=2.0 * T / 3.0,
            evict_bytes=(MM.eviction_bytes(nb, c.attention, c.v)
                         if spec.policy.moves_data else 0.0),
            pair_bw=R.NVLINK_BW, pair_hops=max(rp.feas.pair_hops, 1),
            d2h_bw=R.PCIE_BW, h2d_bw=R.PCIE_BW))
        assert rp.makespan == pytest.approx(res.makespan)
        assert best.makespan <= res.makespan + 1e-12, (best.cand, c)


def rp_max_mfu(survivors):
    return max(survivors, key=lambda rp: rp.mfu, default=None)


# ---------------------------------------------------------------------------
# Paper Table 3 verdicts, reproduced from first principles
# ---------------------------------------------------------------------------
def test_gpt3_verdict_bpipe_wins_under_recompute():
    ranked = plan_config(GPT3_96B, get_config("gpt3-96b"), A100_HBM_BYTES)
    rec = recommend(ranked, "recompute")
    assert rec is not None
    assert rec.cand.kind in S.BPIPE_FAMILY and rec.cand.b == 2
    # the win is memory-made: UNMANAGED 1F1B cannot hold b=2 on an
    # A100-80G (residency-managed 1f1b variants can — that is the point)
    oom = [rp for rp in ranked
           if rp.cand.kind == "1f1b" and rp.cand.b == 2
           and rp.cand.attention == "recompute"
           and rp.cand.residency == "none"]
    assert oom and all(rp.verdict == "infeasible" for rp in oom)
    # flash arm: the paper's BPipe row loses — planner must not pick BPipe
    rec_flash = recommend(ranked, "flash")
    assert rec_flash.cand.kind not in S.BPIPE_FAMILY


def test_llama_verdict_bpipe_rejected_at_break_even():
    ranked = plan_config(LLAMA_65B, get_config("llama-65b"), A100_HBM_BYTES)
    for arm in ("recompute", "flash", None):
        rec = recommend(ranked, arm)
        assert rec is not None
        assert rec.cand.kind not in ("bpipe",), (arm, rec.cand)
    # larger-b plans are feasible but fail the paper's break-even bar:
    # required (B + 4(p-1)) / (B + 2(p-1)) = 156/142, measured Table 5
    # stage gain 57.6/54.5
    rej = [rp for rp in ranked
           if rp.cand.kind == "bpipe" and rp.cand.b == 4
           and rp.cand.attention == "recompute" and rp.cand.cap is None
           and rp.cand.depth == 1]
    assert len(rej) == 1 and rej[0].verdict == "reject"
    assert rej[0].required_gain == pytest.approx(156.0 / 142.0)
    assert rej[0].achieved_gain == pytest.approx(57.6 / 54.5, rel=1e-3)
    # the overall recommendation is a non-BPipe-family plan (Table 3:
    # every LLaMA BPipe row is a regression)
    overall = recommend(ranked)
    assert overall.cand.kind not in S.BPIPE_FAMILY


def test_rejections_cite_required_gain_in_table_and_summary():
    ranked = plan_config(LLAMA_65B, get_config("llama-65b"), A100_HBM_BYTES)
    table = report.format_table(ranked)
    assert "reject" in table and "1.099" in table
    line = report.recommendation_line("llama-65b", ranked, "recompute")
    assert "required 1.099x" in line and "1.057x" in line


def test_planner_cli_end_to_end(capsys):
    import json as _json
    from repro.core import plan as P
    from repro.launch import plan as plan_cli
    plan_cli.main(["--config", "gpt3_96b", "--attention", "recompute",
                   "--top", "3", "--spec-json"])
    out = capsys.readouterr().out
    assert "PLAN gpt3-96b [recompute]: bpipe b=2" in out
    assert "req_gain" in out
    # --spec-json round-trips the FULL spec, residency included
    specs = [_json.loads(ln) for ln in out.splitlines()
             if ln.startswith("{")]
    assert specs
    for rec in specs:
        spec = P.ScheduleSpec.from_dict(rec["spec"])
        assert set(rec["spec"]) == set(P.ScheduleSpec.DICT_KEYS)
        assert spec.to_dict() == rec["spec"]
        assert spec.residency == "bpipe_swap"       # the winning plan's
    plan_cli.main(["--config", "llama_65b", "--csv"])
    out = capsys.readouterr().out
    assert "verdict=reject" in out and ",res=" in out


# ---------------------------------------------------------------------------
# Cap as a search dimension
# ---------------------------------------------------------------------------
def test_looser_cap_trades_evictions_for_memory():
    p, m = 8, 32
    prev_ev = None
    for cap in range(S.bpipe_cap(p), p + 1):
        streams = S.build("bpipe", p, m, cap=cap)
        ev = sum(1 for s in streams.values() for i in s if i.op == S.EVICT)
        peaks = S.peak_stash("bpipe", p, m, cap=cap)
        assert max(peaks[i] for i in range(p // 2)) <= cap + 1
        if prev_ev is not None:
            assert ev <= prev_ev, (cap, ev, prev_ev)
        prev_ev = ev
    assert ev == 0  # cap == 1F1B peak: degenerates to no balancing


def test_executor_honors_custom_cap():
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=4, dtype="float32")
    import jax
    from repro.models import model as M
    from repro.pipeline.executor import PipelineExecutor
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    default = PipelineExecutor(cfg, p=4, kind="bpipe", micro_batch=1)
    loose = PipelineExecutor(cfg, p=4, kind="bpipe", micro_batch=1,
                             cap=S.bpipe_cap(4) + 1)
    r0, r1 = default.step(params, batch), loose.step(params, batch)
    assert abs(float(r0.loss - r1.loss)) < 1e-6
    assert r1.stats.evictions < r0.stats.evictions
    assert max(r1.stats.peak_local[i] for i in (0, 1)) <= S.bpipe_cap(4) + 1


# ---------------------------------------------------------------------------
# Trace -> calibrate round trip (the §4 recipe, programmatically)
# ---------------------------------------------------------------------------
def _traced_step(kind="bpipe", p=4, layers=4, rows=8):
    import jax
    from repro.models import model as M
    from repro.pipeline.executor import PipelineExecutor
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=layers, dtype="float32")
    ex = PipelineExecutor(cfg, p=p, kind=kind, micro_batch=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (rows, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ex.step(params, batch)                  # compile step, not traced
    return ex, cfg, ex.step(params, batch, trace=True)


def test_trace_calibration_changes_simulator_costs(tmp_path):
    ex, cfg, res = _traced_step()
    events = res.events
    assert events is not None
    m = 8
    n_fb = sum(1 for e in events if e.op in (S.F, S.B))
    assert n_fb == 2 * 4 * m
    assert sum(1 for e in events
               if e.op == S.EVICT and e.canonical) == res.stats.evictions
    assert all(e.end >= e.start >= 0.0 for e in events)

    fit = calibrate.fit_trace(events, v=1, b=1)
    assert fit.Tf > 0 and fit.Tb > 0 and fit.samples == len(events)

    base = SIM.SimConfig(p=4, m=m, Tf=1.0, Tb=2.0, kind="bpipe")
    cal = calibrate.apply(fit, base)
    assert (cal.Tf, cal.Tb) == (fit.Tf, fit.Tb) != (1.0, 2.0)
    # the calibrated costs really drive the simulator
    assert SIM.simulate(cal).makespan != SIM.simulate(base).makespan
    assert SIM.simulate(cal).makespan == pytest.approx(
        calibrate.replay(fit, "bpipe", 4, m).makespan)

    # chrome-trace export round-trips losslessly enough to refit
    path = tmp_path / "step.trace.json"
    calibrate.save_chrome_trace(events, str(path))
    fit2 = calibrate.fit_trace(calibrate.load_chrome_trace(str(path)),
                               v=1, b=1)
    assert fit2.Tf == pytest.approx(fit.Tf, rel=1e-6)
    assert fit2.Tb == pytest.approx(fit.Tb, rel=1e-6)


def test_untraced_step_has_no_events():
    ex, cfg, res = _traced_step(kind="1f1b", p=2, layers=2, rows=4)
    import jax
    from repro.models import model as M
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    assert ex.step(params, batch).events is None


def test_two_point_recipe_and_trace_cost_model():
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=2, dtype="float32")
    out = calibrate.measure_stage_gain(cfg, bx=2, by=1, seq=16, m=2)
    assert out["Tx"] > 0 and out["Ty"] > 0 and out["gain"] > 0
    cm = calibrate.TraceCostModel(out["costs_x"])
    n = _n(p=2, B=8)
    assert cm.stage_T(n.replace(b=4), "none") > cm.stage_T(
        n.replace(b=2), "none")
    # saturating shape: larger b always helps per-sample throughput,
    # but with diminishing returns
    g = cm.stage_gain(n, 4, 2, "none")
    assert 1.0 < g < 1.2
    # the traced arm anchors; other arms scale by the analytic factors
    # (a none-mode trace must still charge recompute its re-forward)
    assert cm.stage_T(n, "recompute") > cm.stage_T(n, "none") \
        > cm.stage_T(n, "flash")


def test_interleaved_break_even_uses_interleaved_bubble():
    """A bpipe_interleaved plan whose simulated MFU beats the 1f1b
    baseline must not be rejected by the plain-bubble bar: its ramp is
    (p-1)/v, so the required gain shrinks accordingly (84 GiB admits the
    llama bpipe_interleaved v=4 b=4 plan the 80 GiB budget prunes)."""
    ranked = plan_config(LLAMA_65B, get_config("llama-65b"),
                         84 * 1024**3)
    il = [rp for rp in ranked
          if rp.cand.kind == "bpipe_interleaved" and rp.cand.b == 4
          and rp.cand.v == 4 and rp.cand.attention == "recompute"
          and rp.cand.cap is None and rp.cand.depth == 1]
    assert len(il) == 1 and il[0].verdict == "ok", il
    assert il[0].required_gain == pytest.approx(
        (128 + 4 * 7 / 4) / (128 + 2 * 7))
    # while the plain-bpipe b=4 plan is still rejected at the paper's bar
    plain = [rp for rp in ranked
             if rp.cand.kind == "bpipe" and rp.cand.b == 4
             and rp.cand.attention == "recompute" and rp.cand.cap is None]
    assert plain[0].verdict == "reject"
    assert plain[0].required_gain == pytest.approx(156.0 / 142.0)
