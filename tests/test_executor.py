"""Pipeline executor: BPipe/1F1B/GPipe numerics == non-pipelined reference,
live stash accounting == the memory model's predictions."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import schedule as S
from repro.models import model as M
from repro.pipeline import PipelineExecutor

KEY = jax.random.PRNGKey(11)


def _setup(arch="qwen1.5-0.5b", layers=4, b=8, s=16):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, num_layers=layers, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ref_loss, _ = M.loss_fn(params, batch, cfg)
    ref_grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    return cfg, params, batch, ref_loss, ref_grads


@pytest.mark.parametrize("kind", ["gpipe", "1f1b", "bpipe"])
def test_executor_matches_reference(kind):
    cfg, params, batch, ref_loss, ref_grads = _setup()
    ex = PipelineExecutor(cfg, p=4, kind=kind, micro_batch=2)
    res = ex.step(params, batch)
    assert abs(float(res.loss - ref_loss)) < 1e-5
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(ref_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=1e-4)


def test_executor_hybrid_arch():
    """The paper's technique on a non-dense family (RG-LRU + local attn)."""
    cfg, params, batch, ref_loss, ref_grads = _setup(
        "recurrentgemma-2b", layers=6, b=4, s=12)
    ex = PipelineExecutor(cfg, p=3, kind="bpipe", micro_batch=1)
    res = ex.step(params, batch)
    assert abs(float(res.loss - ref_loss)) < 1e-5
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(ref_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-6, rtol=1e-3)


def test_stash_peaks_match_schedule_model():
    cfg, params, batch, *_ = _setup(b=8)
    for kind in ("1f1b", "bpipe", "gpipe"):
        ex = PipelineExecutor(cfg, p=4, kind=kind, micro_batch=1)
        res = ex.step(params, batch)
        want = S.peak_stash(kind, 4, 8)
        # executor peak may be lower than the merged-trace bound but never
        # above it; local-only peak for 1f1b is exact
        for i in range(4):
            assert res.stats.peak_local[i] <= want[i] + 1
        if kind == "1f1b":
            assert res.stats.peak_local == want
        if kind == "bpipe":
            assert max(res.stats.peak_local.values()) <= S.bpipe_cap(4)
            assert res.stats.evictions == res.stats.loads > 0
            assert res.stats.bytes_moved > 0
        if kind != "bpipe":
            assert res.stats.bytes_moved == 0


def test_executor_moe_arch():
    """MoE through the pipeline. The router load-balance aux is nonlinear
    in batch composition, so per-microbatch aux differs from full-batch
    aux by construction (same in Megatron); with aux weight 0 the
    pipeline is exact, and with aux on it is carried and close."""
    base = get_config("granite-moe-1b-a400m").reduced()
    moe_exact = dataclasses.replace(
        base.moe, capacity_factor=float(base.moe.num_experts),
        router_aux_weight=0.0)
    cfg = dataclasses.replace(base, num_layers=4, dtype="float32",
                              moe=moe_exact)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(KEY, (4, 13), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ref_loss, _ = M.loss_fn(params, batch, cfg)
    ref_grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    ex = PipelineExecutor(cfg, p=2, kind="bpipe", micro_batch=2)
    res = ex.step(params, batch)
    assert abs(float(res.loss - ref_loss)) < 1e-5
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(ref_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-3)
    # aux carried through the pipe when enabled
    cfg_aux = dataclasses.replace(cfg, moe=dataclasses.replace(
        moe_exact, router_aux_weight=0.01))
    res_aux = PipelineExecutor(cfg_aux, p=2, kind="bpipe",
                               micro_batch=2).step(params, batch)
    assert float(res_aux.loss) > float(res.loss)
    # aux magnitude ~ n_layers x weight x E-ish switch loss
    assert abs(float(res_aux.loss - res.loss)) < 0.5


def test_uneven_layer_assignment():
    from repro.pipeline.stage import layer_assignment
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=7)
    assign = layer_assignment(cfg, 3)
    assert [len(a) for a in assign] == [2, 2, 3]
    assert sum(assign, []) == list(range(7))


def test_executor_trains():
    """Three BPipe steps reduce the loss (optimizer integration)."""
    from repro.configs.base import TrainConfig
    from repro.optim import adam
    cfg, params, batch, *_ = _setup(b=4, s=12)
    tcfg = TrainConfig(global_batch=4, steps=10, warmup_steps=1,
                       learning_rate=5e-3)
    ex = PipelineExecutor(cfg, p=2, kind="bpipe", micro_batch=2)
    opt = adam.init(params)
    losses = []
    for _ in range(3):
        res = ex.step(params, batch)
        params, opt, _ = adam.update(params, res.grads, opt, tcfg)
        losses.append(float(res.loss))
    assert losses[-1] < losses[0]
