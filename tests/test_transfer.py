"""The async transfer engine (``repro.transfer`` + the compiled
ISSUE/WAIT IR): golden-pinned overlap timelines (5 kinds x residency at
depth 1 — bit-identical to the pre-refactor serialized engine — and
depth 2), channel pricing/occupancy, the overlap-depth spec dimension,
depth's makespan monotonicity and the host-link overlap sensitivity,
the executor's bounded-depth in-flight runtime, the memory model's
in-flight charge, and the planner's depth dimension."""
import dataclasses
import json
import os

import pytest

from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import schedule as S
from repro.core import simulator as SIM
from repro.core.notation import Notation
from repro.core.schedule import B, EVICT, F, LOAD, OFFLOAD
from repro.memory import policy as respol
from repro.transfer import TransferEngine, channel
from repro.transfer.channel import D2H, H2D, PEER, Channel, channel_key
from repro.transfer.runtime import AsyncTransferRuntime

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "plan_golden.json")
with open(GOLDEN) as f:
    CASES = [c for c in json.load(f) if "residency" in c]

#: The sim knobs every transfer golden case was generated with.
SIM_KW = dict(Tf=1.0, Tb=2.0, t_p2p=0.125, evict_bytes=1.0, pair_bw=2.0,
              pair_hops=1, d2h_bw=4.0, h2d_bw=4.0)


def _spec(case) -> P.ScheduleSpec:
    res = case["residency"]
    return P.ScheduleSpec(case["kind"], case["p"], case["m"],
                          v=max(case["v"], 1), cap=case["cap"],
                          residency="none" if res == "bpipe_swap" else res,
                          depth=case["depth"])


def _case_id(case):
    return (f"{case['kind']}-{case['residency']}-d{case['depth']}")


# ---------------------------------------------------------------------------
# Golden: ISSUE/WAIT streams and overlap timelines, pinned
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", CASES, ids=_case_id)
def test_golden_issue_wait_streams(case):
    sch = P.compile_plan(_spec(case))
    for i in range(case["p"]):
        # the split IR: every residency move is an ISSUE at the original
        # position plus a WAIT where its completion is consumed
        assert [repr(x) for x in sch.streams[i]] \
            == case["split_streams"][str(i)]
        # the collapsed view is the pre-split stream, unchanged
        assert [repr(x) for x in sch.instr_streams()[i]] \
            == case["streams"][str(i)]
    assert dict(sch.peak_stash) == {int(k): n
                                    for k, n in case["peak_stash"].items()}
    assert dict(sch.peak_spilled) == {int(k): n for k, n
                                      in case["peak_spilled"].items()}


@pytest.mark.parametrize("case", CASES, ids=_case_id)
def test_golden_overlap_makespans(case):
    res = SIM.simulate(SIM.SimConfig(spec=_spec(case), **SIM_KW))
    assert res.makespan == case["makespan"]
    assert res.load_stall == case["load_stall"]
    assert res.busy == case["busy"]
    assert res.move_time == case["move_time"]
    assert res.queue_peak == case["queue_peak"]


def test_depth1_rows_equal_their_legacy_twins():
    """The depth-1 transfer engine IS the pre-refactor serialized
    engine: for the kinds the legacy golden set pins, the new rows must
    agree with the old rows exactly (proving the refactor is
    behavior-preserving, not merely self-consistent)."""
    with open(GOLDEN) as f:
        legacy = {(c["kind"], c["p"], c["m"], c["v"], c["cap"]): c
                  for c in json.load(f) if "residency" not in c}
    checked = 0
    for case in CASES:
        if case["depth"] != 1 or case["residency"] not in ("none",
                                                           "bpipe_swap"):
            continue
        old = legacy.get((case["kind"], case["p"], case["m"],
                          max(case["v"], 1), case["cap"])) \
            or legacy.get((case["kind"], case["p"], case["m"],
                           case["v"], case["cap"]))
        if old is None:
            continue
        assert case["streams"] == old["streams"]
        assert case["makespan"] == old["makespan"]
        assert case["load_stall"] == old["load_stall"]
        assert case["busy"] == old["busy"]
        checked += 1
    assert checked >= 4, checked


# ---------------------------------------------------------------------------
# The split IR
# ---------------------------------------------------------------------------
def test_issue_wait_split_shape():
    sch = P.compile_plan(P.ScheduleSpec("bpipe", 4, 8))
    stream = sch.streams[0]
    ev = [x for x in stream if x.op == EVICT]
    ld = [x for x in stream if x.op == LOAD]
    # every move has exactly one ISSUE and one WAIT half
    assert sum(1 for x in ev if x.phase == P.ISSUE) == len(ev) // 2
    assert sum(1 for x in ev if x.is_wait) == len(ev) // 2
    for x in stream:
        if x.op in (F, B):
            assert x.phase == ""
    # a release's ISSUE deps on its own F; its WAIT sits immediately
    # before the matching restore's ISSUE; the restore's WAIT directly
    # follows its ISSUE and deps on the move's own completion
    first_ld = next(i for i, x in enumerate(stream)
                    if x.op == LOAD and x.phase == P.ISSUE)
    prev, nxt = stream[first_ld - 1], stream[first_ld + 1]
    assert prev.op == EVICT and prev.is_wait
    assert prev.key == stream[first_ld].key
    assert prev.dep == (EVICT,) + prev.key
    assert nxt.op == LOAD and nxt.is_wait and nxt.dep == (LOAD,) + nxt.key
    # and the backward comes right after the restore's WAIT
    assert stream[first_ld + 2].op == B
    assert repr(nxt).startswith("LOAD") and "+w@" in repr(nxt)


def test_depth_does_not_change_streams_or_accounting():
    a = P.compile_plan(P.ScheduleSpec("1f1b", 4, 8,
                                      residency="host_offload", depth=1))
    b_ = P.compile_plan(P.ScheduleSpec("1f1b", 4, 8,
                                       residency="host_offload", depth=3))
    assert a.streams == b_.streams
    assert a.peak_stash == b_.peak_stash
    assert a.peak_spilled == b_.peak_spilled


# ---------------------------------------------------------------------------
# ScheduleSpec depth dimension
# ---------------------------------------------------------------------------
def test_depth_validation_and_normalization():
    with pytest.raises(ValueError, match="depth"):
        P.ScheduleSpec("bpipe", 4, 8, depth=0)
    # no channel traffic -> depth is not an identity dimension
    assert P.ScheduleSpec("1f1b", 4, 8, depth=3).depth == 1
    assert P.ScheduleSpec("1f1b", 4, 8, residency="selective_recompute",
                          depth=3).depth == 1
    # data-moving policies keep it
    assert P.ScheduleSpec("bpipe", 4, 8, depth=3).depth == 3
    assert P.ScheduleSpec("1f1b", 4, 8, residency="host_offload",
                          depth=2).depth == 2
    assert "depth=2" in P.ScheduleSpec("bpipe", 4, 8, depth=2).label()
    assert "depth" not in P.ScheduleSpec("bpipe", 4, 8).label()


def test_depth_dict_round_trip():
    spec = P.ScheduleSpec("bpipe", 4, 8, depth=2)
    d = json.loads(json.dumps(spec.to_dict()))
    assert d["depth"] == 2
    assert P.ScheduleSpec.from_dict(d) == spec
    # legacy dicts without the key still load
    legacy = {k: v for k, v in d.items() if k != "depth"}
    assert P.ScheduleSpec.from_dict(legacy) == P.ScheduleSpec("bpipe", 4, 8)
    with pytest.raises(ValueError, match="unknown ScheduleSpec keys"):
        P.ScheduleSpec.from_dict({**d, "deptth": 2})


# ---------------------------------------------------------------------------
# Channels: keys, FIFO pricing, occupancy
# ---------------------------------------------------------------------------
def test_channel_keys_by_mechanism():
    assert channel_key("swap", 0, 3, release=True) == (PEER, 0, 3)
    assert channel_key("swap", 3, 0, release=False) == (PEER, 0, 3)
    assert channel_key("host", 2, None, release=True) == (D2H, 2)
    assert channel_key("host", 2, None, release=False) == (H2D, 2)
    assert channel_key("recompute", 2, None, release=True) is None
    assert channel_key("none", 2, None, release=True) is None


def test_channel_fifo_pricing_and_occupancy():
    ch = Channel((PEER, 0, 3), t_move=2.0, depth=2)
    assert ch.issue(0.0) == (0.0, 2.0)
    # second transfer ready at 1.0 queues behind the first
    assert ch.issue(1.0) == (2.0, 4.0)
    st = ch.stats
    assert st.moves == 2 and st.busy == 4.0 and st.queue_peak == 2
    # a transfer ready after the link drained starts immediately
    assert ch.issue(10.0) == (10.0, 12.0)
    assert ch.stats.queue_peak == 2
    assert ch.stats.utilization(12.0) == pytest.approx(0.5)


def test_channel_admission_bounds_occupancy_not_times():
    """Bounded admission: occupancy never exceeds depth, and because the
    link serializes, the admission delay provably never changes
    start/end times — a depth-1 and a depth-3 channel price the same
    burst identically, differing only in queue_peak."""
    bursts = [0.0, 0.1, 0.2, 0.3, 5.0]
    d1 = Channel((D2H, 0), t_move=1.0, depth=1)
    d3 = Channel((D2H, 0), t_move=1.0, depth=3)
    assert [d1.issue(t) for t in bursts] == [d3.issue(t) for t in bursts]
    assert d1.stats.queue_peak == 1
    assert 1 < d3.stats.queue_peak <= 3


def test_engine_routes_policies_to_channels():
    sch = P.compile_plan(P.ScheduleSpec("bpipe", 4, 8))
    eng = TransferEngine(sch, t_peer=0.5)
    s, e = eng.issue(respol.BPIPE_SWAP, 0, ready=1.0, release=True)
    assert (s, e) == (1.0, 1.5)
    assert set(eng.stats()) == {(PEER, 0, 3)}
    # recompute has no channel: completes at ready
    from repro.memory.recompute import SELECTIVE_RECOMPUTE
    assert eng.issue(SELECTIVE_RECOMPUTE, 0, 2.0, release=True) == (2.0, 2.0)
    assert eng.queue_peak == 1


# ---------------------------------------------------------------------------
# Overlap semantics: depth monotonicity + the host-link sensitivity
# ---------------------------------------------------------------------------
def _sim(spec, **kw):
    base = dict(Tf=1.0, Tb=2.0, evict_bytes=1.0)
    base.update(kw)
    return SIM.simulate(SIM.SimConfig(spec=spec, **base))


def test_deeper_overlap_never_hurts():
    """Issue-early is monotone: a deeper prefetch window can only start
    transfers earlier, so makespan and stall are non-increasing in
    depth."""
    for res, kw in (("host_offload", dict(d2h_bw=0.3, h2d_bw=0.3)),
                    ("host_offload", dict(d2h_bw=2.0, h2d_bw=2.0))):
        prev = None
        for d in (1, 2, 3, 4):
            r = _sim(P.ScheduleSpec("1f1b", 8, 32, residency=res, depth=d),
                     **kw)
            if prev is not None:
                assert r.makespan <= prev.makespan + 1e-9
                assert r.load_stall <= prev.load_stall + 1e-9
            prev = r


def test_depth_two_hides_the_host_link():
    """The paper-level claim this engine exists to reproduce: whether
    offload overlap hides the PCIe-class link *decides* the arm's cost.
    At depth 1 the serialized prefetch stalls; depth 2 overlaps the
    same traffic to zero stall."""
    spec1 = P.ScheduleSpec("1f1b", 8, 32, residency="host_offload", depth=1)
    spec2 = dataclasses.replace(spec1, depth=2)
    kw = dict(d2h_bw=0.3, h2d_bw=0.3)
    r1 = _sim(spec1, **kw)
    r2 = _sim(spec2, **kw)
    assert r1.load_stall > 0.0
    assert r2.load_stall == 0.0
    assert r2.makespan < r1.makespan
    # the overlap is visible as queue occupancy, not a special case:
    # the saturated link runs multiple transfers in flight
    assert r2.queue_peak == 2
    # same bytes moved either way — the win is purely overlap
    assert r2.move_time == pytest.approx(r1.move_time)
    assert spec2.depth == 2


def test_depth1_prefetch_threshold_is_the_pinned_special_case():
    """The old hard-coded stall threshold (Tf+Tb)/(2v) is now emergent:
    at depth 1 the swap stalls just above it (tests/test_plan.py pins
    the exact boundary) and the engine reports the pair link saturated
    (utilization ~1 in steady state)."""
    p, m, Tf, Tb, v = 8, 32, 1.0, 2.0, 2
    thr = (Tf + Tb) / (2 * v)
    spec = P.ScheduleSpec("bpipe_interleaved", p, m, v=v)
    above = _sim(spec, evict_bytes=thr * 1.1, pair_bw=1.0)
    assert above.load_stall > 0.0
    pair_stats = [s for k, s in above.channels.items() if k[0] == PEER]
    assert pair_stats and all(s.moves > 0 for s in pair_stats)


def test_simulator_order_invariance_single_issuer():
    """Channel FIFO order equals per-stage stream order, so for every
    channel with a single issuing stage (all built-in policies at
    default caps) the priced timeline is engine-order invariant."""
    for spec in (P.ScheduleSpec("bpipe", 8, 16),
                 P.ScheduleSpec("bpipe_interleaved", 8, 16, v=2),
                 P.ScheduleSpec("1f1b", 8, 16, residency="host_offload"),
                 P.ScheduleSpec("1f1b", 8, 16,
                                residency="selective_recompute")):
        kw = dict(evict_bytes=1.4, pair_bw=1.0, d2h_bw=1.0, h2d_bw=1.0)
        a = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0, **kw),
                         greedy=True)
        b_ = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0, **kw),
                          greedy=False)
        assert a.makespan == b_.makespan, spec
        assert a.timeline == b_.timeline
        assert a.load_stall == b_.load_stall


# ---------------------------------------------------------------------------
# Memory model: overlap buys speed with bytes
# ---------------------------------------------------------------------------
def test_memory_model_charges_inflight_depth():
    n = Notation(a=4, b=2, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
    unit = MM.act_bytes_per_stage(n, "recompute", 1)
    d1 = MM.per_stage_memory(n, "recompute", P.ScheduleSpec(
        "1f1b", 4, n.num_micro, residency="host_offload", depth=1))
    d3 = MM.per_stage_memory(n, "recompute", P.ScheduleSpec(
        "1f1b", 4, n.num_micro, residency="host_offload", depth=3))
    sch = P.compile_plan(P.ScheduleSpec("1f1b", 4, n.num_micro,
                                        residency="host_offload"))
    for i in range(4):
        extra = 2 * unit if sch.num_loads[i] else 0.0
        assert d3[i].act_bytes == pytest.approx(d1[i].act_bytes + extra)
    # recompute moves no bytes: depth cannot change its footprint
    r1 = MM.per_stage_memory(n, "recompute", P.ScheduleSpec(
        "1f1b", 4, n.num_micro, residency="selective_recompute", depth=1))
    r3 = MM.per_stage_memory(n, "recompute", P.ScheduleSpec(
        "1f1b", 4, n.num_micro, residency="selective_recompute", depth=3))
    assert [s.act_bytes for s in r1] == [s.act_bytes for s in r3]


# ---------------------------------------------------------------------------
# Executor: the bounded-depth in-flight runtime
# ---------------------------------------------------------------------------
def test_async_runtime_depth_cap_and_fifo_wait():
    retired = []

    class _Payload:
        def __init__(self, n):
            self.n = n
    rt = AsyncTransferRuntime(depth=2)
    import repro.transfer.runtime as rtmod
    orig = rtmod._block
    rtmod._block = lambda p: retired.append(p.n)
    try:
        key = (D2H, 0)
        for n_ in range(4):
            rt.submit(key, ("OFFLOAD", 0, n_, 0),
                      lambda n_=n_: _Payload(n_))
        # depth 2: the slot is reserved BEFORE the copy launches —
        # submitting #2 retires #0 first, #3 retires #1
        assert retired == [0, 1]
        assert rt.inflight_peak == 2   # never exceeds the cap
        rt.wait(key, ("OFFLOAD", 0, 3, 0))   # FIFO: retires 2 then 3
        assert retired == [0, 1, 2, 3]
        rt.submit(key, ("OFFLOAD", 0, 9, 0), lambda: _Payload(9))
        rt.drain()
        assert retired[-1] == 9
        assert rt.submitted == 5 and rt.retired == 5
        # waiting on a unit the depth cap already retired is a no-op —
        # it must NOT drain (block on) newer unrelated transfers
        for n_ in (10, 11, 12):
            rt.submit(key, ("OFFLOAD", 0, n_, 0),
                      lambda n_=n_: _Payload(n_))
        assert retired[-1] == 10          # cap retired the oldest
        rt.wait(key, ("OFFLOAD", 0, 10, 0))
        assert retired[-1] == 10          # 11/12 still in flight
        assert len(rt._q[key]) == 2
        rt.drain()
        # channel-less mechanisms just run the thunk
        assert rt.submit(None, "u", lambda: "payload") == "payload"
        rt.wait(None, "u")
    finally:
        rtmod._block = orig


@pytest.fixture(scope="module")
def exec_setup():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=4, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (8, 9), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ref_loss, _ = M.loss_fn(params, batch, cfg)
    return cfg, params, batch, ref_loss


@pytest.mark.parametrize("depth", [1, 2])
def test_executor_depth_bit_identical_and_bounded(exec_setup, depth):
    """Overlap depth changes WHEN copies are waited on, never WHAT they
    compute: loss/grads are bit-identical across depths, and the
    runtime's in-flight peak respects the cap."""
    import jax
    import numpy as np
    from repro.pipeline import PipelineExecutor
    cfg, params, batch, ref_loss = exec_setup
    spec = P.ScheduleSpec("1f1b", 4, 8, residency="host_offload",
                          depth=depth)
    ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
    r = ex.step(params, batch)
    assert abs(float(r.loss - ref_loss)) < 1e-5
    assert r.stats.offloads == r.stats.fetches > 0
    assert 1 <= r.stats.transfers_inflight_peak <= depth
    base = PipelineExecutor(
        cfg, spec=P.ScheduleSpec("1f1b", 4, 8, residency="host_offload"),
        micro_batch=1).step(params, batch)
    assert float(r.loss) == float(base.loss)
    for a, b_ in zip(jax.tree.leaves(r.grads), jax.tree.leaves(base.grads)):
        assert np.array_equal(np.asarray(a), np.asarray(b_))


def test_executor_trace_separates_wait_halves(exec_setup):
    from repro.pipeline import PipelineExecutor
    cfg, params, batch, _ = exec_setup
    ex = PipelineExecutor(cfg, spec=P.ScheduleSpec("bpipe", 4, 8),
                          micro_batch=1)
    r = ex.step(params, batch, trace=True)
    ev = [e for e in r.events if e.op == EVICT and e.track == "compute"]
    assert {e.phase for e in ev} == {"issue", "wait"}
    # canonical move counts stay one-per-transfer (calibrate contract);
    # WAIT halves and channel-occupancy spans ride along separately
    assert sum(1 for e in ev if e.canonical) == r.stats.evictions
    assert sum(1 for e in r.events
               if e.op == LOAD and e.canonical) == r.stats.loads
    assert sum(1 for e in r.events if e.op == EVICT
               and e.track == "channel") == r.stats.evictions


# ---------------------------------------------------------------------------
# Planner: the overlap-depth dimension
# ---------------------------------------------------------------------------
def test_planner_searches_depth_dimension():
    from repro.planner import SearchSpace
    from repro.planner.space import enumerate_candidates
    n = Notation(a=4, b=1, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
    cands = list(enumerate_candidates(
        n, SearchSpace(kinds=("1f1b", "bpipe"), attentions=("recompute",),
                       depths=(1, 2))))
    depths = {(c.residency, c.depth) for c in cands}
    assert ("bpipe_swap", 2) in depths and ("host_offload", 2) in depths
    # no depth ladder where no bytes move
    assert ("none", 2) not in depths
    assert ("selective_recompute", 2) not in depths
    # depth 1 enumerates before depth 2 (ties resolve to less memory)
    first = next(c for c in cands if c.residency == "bpipe_swap")
    assert first.depth == 1
    two = next(c for c in cands if c.depth == 2)
    assert "d=2" in two.label()
    assert two.spec(4).depth == 2
