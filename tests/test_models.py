"""Model-layer correctness: attention variants, recurrent cells,
decode==forward consistency across families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models import model as M
from repro.models import recurrent as R
from repro.models import xlstm as X

KEY = jax.random.PRNGKey(7)


def _fp32(cfg, **kw):
    return dataclasses.replace(cfg, dtype="float32", **kw)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _naive_attention(q, k, v, causal=True, window=0):
    b, sq, nq, hd = q.shape
    nkv = k.shape[2]
    rep = nq // nkv
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqnh,bknh->bnqk", q, kf) / np.sqrt(hd)
    mask = jnp.ones((sq, sq), bool)
    if causal:
        mask &= jnp.tril(mask)
    if window:
        qi = jnp.arange(sq)[:, None]
        mask &= (qi - jnp.arange(sq)[None, :]) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bnqk,bknh->bqnh", p, vf)


@pytest.mark.parametrize("nq,nkv,window", [(4, 4, 0), (4, 1, 0), (8, 2, 8)])
def test_sdpa_matches_naive(nq, nkv, window):
    cfg = _fp32(get_config("qwen1.5-0.5b").reduced())
    b, s, hd = 2, 24, 16
    q = jax.random.normal(KEY, (b, s, nq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    got = A._sdpa(q, k, v, cfg, pos, pos, causal=True, window=window)
    want = _naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_softcap_changes_scores_bounded():
    from repro.models.layers import softcap
    x = jnp.linspace(-100, 100, 50)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


@pytest.mark.slow
def test_ring_buffer_cache_consistency():
    """Local-attn ring cache: decode matches full forward past the wrap."""
    cfg = _fp32(get_config("recurrentgemma-2b").reduced())
    assert cfg.window_size == 32
    params = M.init_params(KEY, cfg)
    b, s = 1, 48  # > window so the ring wraps
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits, _ = M.forward(params, {"tokens": toks, "labels": toks}, cfg)
    st = M.init_decode_state(cfg, b, s)
    lg, st, _ = M.prefill(params, {"tokens": toks[:, :8]}, cfg, st)
    errs = [float(jnp.max(jnp.abs(lg - logits[:, 7])))]
    for i in range(8, s):
        lg, st = M.decode_step(params, toks[:, i], jnp.int32(i), st, cfg)
        errs.append(float(jnp.max(jnp.abs(lg - logits[:, i]))))
    assert max(errs) < 2e-4, errs


# ---------------------------------------------------------------------------
# recurrent cells
# ---------------------------------------------------------------------------
def test_rglru_scan_equals_stepwise():
    cfg = _fp32(get_config("recurrentgemma-2b").reduced())
    p = R.init_rglru_block(KEY, cfg)
    x = jax.random.normal(KEY, (2, 20, cfg.rnn_width))
    h_scan = R.rglru_scan(p, x)
    h = jnp.zeros((2, cfg.rnn_width))
    outs = []
    for t in range(20):
        out, h = R.rglru_step(p, x[:, t], h)
        outs.append(out)
    h_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_step),
                               atol=1e-5, rtol=1e-4)


def test_rglru_decay_bounded():
    cfg = _fp32(get_config("recurrentgemma-2b").reduced())
    p = R.init_rglru_block(KEY, cfg)
    a, _ = R._gates(p, jax.random.normal(KEY, (1, 8, cfg.rnn_width)))
    assert float(jnp.min(a)) > 0.0 and float(jnp.max(a)) < 1.0


@pytest.mark.parametrize("s", [16, 24, 33, 64])
def test_mlstm_chunkwise_equals_sequential(s):
    cfg = _fp32(get_config("xlstm-125m").reduced())
    p = X.init_mlstm(KEY, cfg)
    x = jax.random.normal(KEY, (2, s, cfg.d_model))
    h1, s1 = X.mlstm_sequential(p, x, cfg)
    h2, s2 = X.mlstm_chunkwise(p, x, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1["C"]), np.asarray(s2["C"]),
                               atol=2e-4, rtol=1e-3)


def test_slstm_step_matches_scan():
    cfg = _fp32(get_config("xlstm-125m").reduced())
    p = X.init_slstm(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model))
    h_scan, st_final = X.slstm_scan(p, x, cfg)
    st = X.init_slstm_state(cfg, 2)
    for t in range(12):
        out, st = X.apply_slstm_block_step(p, x[:, t:t+1], cfg, st)
    np.testing.assert_allclose(np.asarray(st["c"]), np.asarray(st_final["c"]),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# prefill + decode == forward, across families
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [
    "recurrentgemma-2b", "qwen3-14b", "gemma2-9b", "xlstm-125m",
    "qwen1.5-0.5b", "whisper-small", "internvl2-1b", "granite-moe-1b-a400m",
    "llama4-scout-17b-a16e", "qwen1.5-32b",
])
def test_decode_matches_forward(arch):
    cfg = _fp32(get_config(arch).reduced())
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 24
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    npre = cfg.num_prefix_embeds if cfg.frontend == "vision" else 0
    if npre:
        batch["prefix_embeds"] = jax.random.normal(KEY, (b, npre, cfg.d_model))
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(KEY, (b, 16, cfg.d_model))
    logits, _ = M.forward(params, batch, cfg)
    sp = s - 4
    st = M.init_decode_state(cfg, b, s + npre)
    pre = dict(batch)
    pre["tokens"] = toks[:, :sp]
    lg, st, enc = M.prefill(params, pre, cfg, st)
    errs = [float(jnp.max(jnp.abs(lg - logits[:, sp - 1])))]
    for i in range(sp, s):
        lg, st = M.decode_step(params, toks[:, i], jnp.int32(i + npre), st,
                               cfg, enc_states=enc)
        errs.append(float(jnp.max(jnp.abs(lg - logits[:, i]))))
    assert max(errs) < 2e-4, (arch, errs)


def test_moe_aux_loss_and_balance():
    import repro.models.moe as moe_mod
    cfg = _fp32(get_config("granite-moe-1b-a400m").reduced())
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    y, aux = moe_mod.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0.0
    # capacity respected: no NaNs even under heavy imbalance
    x2 = jnp.ones((2, 32, cfg.d_model))
    y2, _ = moe_mod.apply_moe(p, x2, cfg)
    assert np.isfinite(np.asarray(y2)).all()


def test_moe_matches_dense_loop_when_no_drops():
    """Scatter-dispatch MoE == per-token expert loop (cap = no drops)."""
    import repro.models.moe as moe_mod
    cfg = _fp32(get_config("granite-moe-1b-a400m").reduced())
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, cfg.d_model))
    y, _ = moe_mod.apply_moe(p, x, cfg)
    gates, idx, _ = moe_mod.route(p, x, cfg)
    want = jnp.zeros_like(x)
    for t in range(16):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe.top_k):
            e = int(idx[0, t, j])
            h = jax.nn.silu(x[0, t] @ p["wi"][e]) * (x[0, t] @ p["wg"][e])
            acc += gates[0, t, j] * (h @ p["wo"][e])
        want = want.at[0, t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
