"""The unified event stream (repro.obs): schema, zero-cost contract,
exporter round trip, metrics folds, and sim-vs-real audits.

Three repo invariants live here (docs/observability.md):

  * zero cost when no observer is attached — the simulator's golden
    makespans/timelines and the executor's events=None default are
    bit-identical to the pre-instrumentation engine,
  * one lossless trace format — every span field (WAIT ``+w`` halves,
    sequence slices ``.sN``, channel keys, HBM samples) survives the
    Perfetto round trip, and legacy suffix-spelled traces still load,
  * one instruction census — the simulator and the real executor event
    streams of the SAME ScheduleSpec contain the same instruction set.
"""
import json

import pytest
from hypothesis import given, settings, strategies as st

import repro.core.plan as P
import repro.core.simulator as SIM
from repro.core.schedule import B, EVICT, F, LOAD
from repro.obs import CHANNEL, COMPUTE, ISSUE, WAIT, Recorder, Timeline
from repro.obs import compare as OC
from repro.obs import events as OE
from repro.obs import export as OX
from repro.obs import metrics as OM
from repro.planner import calibrate


def _sim_cfg(spec, **kw):
    kw.setdefault("Tf", 1.0)
    kw.setdefault("Tb", 2.0)
    kw.setdefault("t_p2p", 0.125)
    return SIM.SimConfig(spec=spec, **kw)


def _record(cfg):
    rec = Recorder()
    res = SIM.simulate(cfg, observer=rec)
    return rec, res


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
def test_phase_constants_match_plan_ir():
    assert (OE.ISSUE, OE.WAIT) == (P.ISSUE, P.WAIT)


def test_span_key_matches_planned_instr_identity():
    spec = P.ScheduleSpec("bpipe", 4, 8, cap=2)
    sch = P.compile_plan(spec)
    rec, _ = _record(_sim_cfg(spec, evict_bytes=1.0, pair_bw=2.0))
    instr_keys = {(x.op, i, x.mb, x.chunk, x.sl, x.phase)
                  for i, stream in sch.streams.items() for x in stream}
    assert rec.keys() == instr_keys
    # exactly one compute span per compiled instruction — census, not
    # just coverage
    assert len(rec.compute_spans()) == sch.size


def test_span_label_spells_legacy_suffixes():
    s = OE.make(EVICT, 3, 3, chunk=1, sl=2, phase=WAIT)
    assert s.label == "EVICT3.c1.s2+w"
    assert not s.canonical and s.is_wait
    assert OE.make(F, 0, 1).canonical


# ---------------------------------------------------------------------------
# Zero-cost contract
# ---------------------------------------------------------------------------
def test_sim_observer_is_zero_cost_on_golden_cases():
    cases = [c for c in json.load(open("tests/golden/plan_golden.json"))
             if "residency" not in c]
    assert cases
    for c in cases[::3]:   # every 3rd case keeps this under a second
        spec = P.ScheduleSpec(c["kind"], c["p"], c["m"],
                              v=max(c["v"], 1), cap=c["cap"],
                              seq_chunks=c.get("seq_chunks", 1))
        cfg = _sim_cfg(spec, evict_bytes=1.0, pair_bw=2.0, pair_hops=1)
        base = SIM.simulate(cfg)
        rec, res = _record(cfg)
        assert res.makespan == base.makespan == c["makespan"]
        assert res.timeline == base.timeline
        assert rec.makespan == res.makespan


def test_dispatch_order_is_engine_order():
    spec = P.ScheduleSpec("1f1b", 2, 4)
    sch = P.compile_plan(spec)
    rec, _ = _record(_sim_cfg(spec))
    assert len(rec.dispatches) == sum(len(s) for s in sch.streams.values())
    # per stage, dispatch order IS stream order (streams are consumed
    # strictly in order)
    for i, stream in sch.streams.items():
        got = [d.key for d in rec.dispatches if d.stage == i]
        want = [(x.op, i, x.mb, x.chunk, x.sl, x.phase) for x in stream]
        assert got == want


# ---------------------------------------------------------------------------
# Exporter round trip
# ---------------------------------------------------------------------------
span_strategy = st.tuples(
    st.integers(0, 4),            # op index
    st.integers(0, 5),            # stage
    st.integers(0, 7),            # mb
    st.integers(0, 2),            # chunk
    st.integers(0, 3),            # sl
    st.integers(0, 2),            # phase index
    st.floats(0.0, 100.0),        # start
    st.floats(0.0, 10.0),         # duration
    st.integers(0, 3),            # track/channel selector
)
_OPS = (F, B, EVICT, LOAD, "OFFLOAD")
_PHASES = ("", ISSUE, WAIT)
_CHANNELS = (None, ("peer", 0, 3), ("d2h", 1), ("h2d", 2))


def _mk_span(t):
    op, stage, mb, chunk, sl, ph, start, dur, chan = t
    channel = _CHANNELS[chan]
    return OE.make(_OPS[op], stage, mb, chunk, sl, _PHASES[ph],
                   start=start, end=start + dur,
                   track=CHANNEL if channel else COMPUTE,
                   channel=channel,
                   hbm=float(mb * 100) if channel is None else None)


@settings(max_examples=40)
@given(st.lists(span_strategy, min_size=1, max_size=30))
def test_export_round_trip_is_lossless(tuples):
    import os
    import tempfile
    spans = [_mk_span(t) for t in tuples]
    fd, path = tempfile.mkstemp(suffix=".trace.json")
    os.close(fd)
    try:
        OX.save_trace(spans, path)
        back = OX.load_trace(path)
    finally:
        os.unlink(path)
    assert len(back) == len(spans)
    # multiset equality over every structured field + times
    def norm(ss):
        return sorted((s.key, round(s.start, 6), round(s.duration, 6),
                       s.track, s.channel, s.hbm) for s in ss)
    assert norm(back) == norm(spans)


def test_round_trip_keeps_wait_and_slice_fields(tmp_path):
    """Regression for the ad-hoc serializer this exporter replaced: a
    sliced, depth-2 simulated trace must reload with its WAIT halves and
    slice indices intact (it used to fold them into op strings and lose
    them, mis-binning move medians on re-fit)."""
    spec = P.ScheduleSpec("bpipe", 6, 6, cap=4, seq_chunks=2, depth=2)
    rec, _ = _record(_sim_cfg(spec, evict_bytes=1.0, pair_bw=2.0))
    assert any(s.sl > 0 for s in rec.spans)
    assert any(s.is_wait for s in rec.spans)
    assert any(s.track == CHANNEL for s in rec.spans)
    path = str(tmp_path / "sliced.trace.json")
    OX.save_trace(rec.spans, path)
    back = OX.load_trace(path)
    assert {s.key for s in back} == {s.key for s in rec.spans}
    assert (sum(1 for s in back if s.is_wait)
            == sum(1 for s in rec.spans if s.is_wait))
    assert (sum(1 for s in back if s.track == CHANNEL)
            == sum(1 for s in rec.spans if s.track == CHANNEL))
    f1 = calibrate.fit_trace(rec.spans, v=1, seq_chunks=2)
    f2 = calibrate.fit_trace(back, v=1, seq_chunks=2)
    assert (f1.Tf, f1.Tb, f1.t_evict, f1.t_load) == pytest.approx(
        (f2.Tf, f2.Tb, f2.t_evict, f2.t_load))


def test_loader_parses_legacy_suffix_traces(tmp_path):
    """Pre-obs traces spelled slices/waits as name suffixes with no
    structured args — the loader must still recover them."""
    legacy = {"traceEvents": [
        {"ph": "X", "pid": 0, "tid": 2, "name": "F0.s1", "cat": "F.s1",
         "ts": 0.0, "dur": 1e6, "args": {"mb": 0}},
        {"ph": "X", "pid": 0, "tid": 2, "name": "LOAD3+w", "cat": "LOAD+w",
         "ts": 1.0e6, "dur": 0.5e6, "args": {"mb": 3}},
        {"ph": "M", "pid": 0, "name": "thread_name"},
    ]}
    path = tmp_path / "legacy.json"
    path.write_text(json.dumps(legacy))
    back = OX.load_trace(str(path))
    assert len(back) == 2
    f, load = sorted(back, key=lambda s: s.start)
    assert (f.op, f.sl, f.phase, f.stage) == (F, 1, "", 2)
    assert f.duration == pytest.approx(1.0)
    assert (load.op, load.phase, load.mb) == (LOAD, WAIT, 3)


def test_chrome_events_carry_structured_args_and_counters():
    spans = [OE.make(F, 0, 0, start=0.0, end=1.0, hbm=64.0),
             OE.make(EVICT, 0, 1, phase=ISSUE, start=1.0, end=1.25),
             OE.make(EVICT, 0, 1, phase="", start=1.0, end=2.0,
                     track=CHANNEL, channel=("peer", 0, 3))]
    doc = OX.to_chrome(spans, counters={0: [(0.0, 0.0), (1.0, 64.0)]})
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert all("op" in e["args"] for e in xs)
    chan = [e for e in xs if e["args"]["track"] == CHANNEL]
    assert chan and chan[0]["pid"] != xs[0]["pid"]
    assert any(e["ph"] == "C" for e in events)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
def test_metrics_agree_with_simulator_accounting():
    spec = P.ScheduleSpec("bpipe", 4, 8, cap=2)
    cfg = _sim_cfg(spec, evict_bytes=1.0, pair_bw=2.0)
    rec, res = _record(cfg)
    met = OM.compute(rec.spans, p=spec.p, channel_stats=res.channels)
    assert met.makespan == res.makespan
    assert met.bubble_fraction == pytest.approx(res.bubble_fraction)
    for i, s in enumerate(met.stages):
        assert s.busy == pytest.approx(res.busy[i])
    assert {c.key for c in met.channels} == set(res.channels)
    for c in met.channels:
        st_ = res.channels[c.key]
        assert c.moves == st_.moves
        assert c.busy == pytest.approx(st_.busy)
        assert c.stall == pytest.approx(st_.stall)
        assert c.queue_peak == st_.queue_peak
    assert 0.0 < met.channel_occupancy() <= 1.0


def test_warmup_steady_drain_partition_the_step():
    spec = P.ScheduleSpec("1f1b", 4, 8)
    rec, res = _record(_sim_cfg(spec))
    met = OM.compute(rec.spans, p=spec.p)
    for s in met.stages:
        assert s.warmup >= 0 and s.steady >= 0 and s.drain >= 0
        assert s.warmup + s.steady + s.drain <= res.makespan + 1e-9
        assert 0.0 <= s.bubble_fraction < 1.0


def test_hbm_timeline_repriced_matches_stash_peaks():
    spec = P.ScheduleSpec("bpipe", 4, 8, cap=2)
    sch = P.compile_plan(spec)
    rec, _ = _record(_sim_cfg(spec, evict_bytes=1.0, pair_bw=2.0))
    series = OM.hbm_timeline(rec.spans, sch.partner, unit_bytes=1.0,
                             p=spec.p)
    peaks = OM.hbm_peaks(series)
    # unit weights = stash units: each stage's re-priced peak is at
    # least the plan's peak stash — the evictor stages (0, 1) ride one
    # unit above their cap while an eviction is in flight (the release
    # lands at the EVICT span's end, after the next F has stashed),
    # which is exactly the transient a byte *timeline* should show and
    # instantaneous stash accounting cannot
    assert all(peaks[i] >= float(sch.peak_stash[i])
               for i in range(spec.p))
    assert peaks == {0: 3.0, 1: 3.0, 2: 4.0, 3: 4.0}


def test_metrics_mfu_line():
    spec = P.ScheduleSpec("1f1b", 2, 4)
    rec, res = _record(_sim_cfg(spec))
    met = OM.compute(rec.spans, p=2, model_flops=12.0, t=1, peak_flops=1.0)
    assert met.mfu == pytest.approx(
        SIM.mfu_from_sim(res, 12.0, 2, 1, 1.0))


def test_fit_trace_bins_waits_and_skips_channel_spans():
    spans = [OE.make(F, 0, 0, start=0.0, end=1.0),
             OE.make(B, 0, 0, start=1.0, end=3.0),
             OE.make(LOAD, 0, 1, phase=ISSUE, start=3.0, end=3.5),
             OE.make(LOAD, 0, 1, phase=WAIT, start=3.5, end=4.5),
             OE.make(LOAD, 0, 1, start=3.0, end=3.5,
                     track=CHANNEL, channel=("peer", 0, 1))]
    fit = calibrate.fit_trace(spans)
    assert (fit.Tf, fit.Tb) == (1.0, 2.0)
    assert fit.t_load == 0.5       # the ISSUE half, not the WAIT barrier
    assert fit.samples == 5        # but the census counts everything


# ---------------------------------------------------------------------------
# Compare: sim-vs-real alignment
# ---------------------------------------------------------------------------
def test_compare_scaled_self_has_unit_skew_and_zero_divergence():
    spec = P.ScheduleSpec("bpipe", 4, 8, cap=2)
    rec, _ = _record(_sim_cfg(spec, evict_bytes=1.0, pair_bw=2.0))
    scaled = [OE.make(s.op, s.stage, s.mb, s.chunk, s.sl, s.phase,
                      start=2.0 * s.start, end=2.0 * s.end,
                      track=s.track, channel=s.channel)
              for s in rec.spans]
    rep = OC.compare(rec.spans, scaled, label="self*2")
    assert rep.instruction_sets_match
    assert rep.time_scale == pytest.approx(2.0)
    assert rep.max_order_divergence == 0.0
    assert all(s.skew == pytest.approx(1.0) for s in rep.op_skew)
    assert "self*2" in rep.format()
    assert json.dumps(rep.to_dict())


def test_compare_flags_census_and_order_divergence():
    spec = P.ScheduleSpec("1f1b", 2, 4)
    rec, _ = _record(_sim_cfg(spec))
    spans = rec.compute_spans()
    # drop one instruction and swap two starts on stage 0
    broken = [s for s in spans if not (s.op == B and s.mb == 3
                                       and s.stage == 1)]
    f0 = [s for s in broken if s.stage == 0 and s.op == F][:2]
    swapped = []
    for s in broken:
        if s is f0[0]:
            swapped.append(OE.make(s.op, s.stage, s.mb, start=f0[1].start,
                                   end=f0[1].start + s.duration))
        elif s is f0[1]:
            swapped.append(OE.make(s.op, s.stage, s.mb, start=f0[0].start,
                                   end=f0[0].start + s.duration))
        else:
            swapped.append(s)
    rep = OC.compare(spans, swapped)
    assert not rep.instruction_sets_match
    assert [k[0] for k in rep.missing_in_real] == [B]
    assert rep.order_div[0] > 0.0


def test_order_divergence_bounds():
    assert OC.order_divergence([1, 2, 3], [1, 2, 3]) == 0.0
    assert OC.order_divergence([1, 2, 3], [3, 2, 1]) == 1.0
    assert OC.order_divergence([], []) == 0.0


# ---------------------------------------------------------------------------
# The executor side (real jax numerics)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def exec_setup():
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=8, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (8, 17), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return cfg, params, batch


AUDIT_SPECS = [
    P.ScheduleSpec("bpipe", 4, 8, cap=2),
    P.ScheduleSpec("1f1b", 4, 8, residency="host_offload", depth=2),
    P.ScheduleSpec("1f1b", 4, 8, residency="selective_recompute"),
    P.ScheduleSpec("bpipe", 4, 8, cap=2, seq_chunks=2),
]


@pytest.mark.parametrize("spec", AUDIT_SPECS, ids=lambda s: s.label())
def test_sim_and_executor_streams_share_one_instruction_set(
        exec_setup, spec):
    """The differential census invariant: for the same spec, the
    simulated and the real event streams contain the same instruction
    set — every key the model prices is executed, and vice versa."""
    from repro.pipeline.executor import PipelineExecutor
    cfg, params, batch = exec_setup
    ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
    res = ex.step(params, batch, trace=True)
    costs = calibrate.fit_trace(res.events, v=spec.v, b=1,
                                seq_chunks=spec.seq_chunks)
    rec, _ = _record(SIM.SimConfig(spec=spec, Tf=costs.Tf, Tb=costs.Tb,
                                   evict_bytes=1.0, pair_bw=2.0,
                                   d2h_bw=2.0, h2d_bw=2.0))
    rep = OC.compare(rec.spans, res.events, label=spec.label())
    assert rep.instruction_sets_match, rep.format()
    assert rep.sim_count == rep.real_count
    assert rep.time_scale > 0


def test_executor_trace_records_hbm_samples_and_timeline(exec_setup):
    from repro.pipeline.executor import PipelineExecutor
    cfg, params, batch = exec_setup
    spec = P.ScheduleSpec("bpipe", 4, 8, cap=2)
    ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
    assert ex.step(params, batch).events is None   # zero-observer default
    res = ex.step(params, batch, trace=True)
    hbm = [s for s in res.events if s.hbm is not None]
    assert hbm and max(s.hbm for s in hbm) > 0
    series = OM.hbm_timeline(res.events, P.compile_plan(spec).partner,
                             unit_bytes=0.0, p=spec.p)
    assert max(v for ser in series.values() for _, v in ser) > 0


def test_custom_observer_streams_executor_spans(exec_setup):
    """observer= without trace=True: spans stream to the caller's
    observer and the step result carries no event list."""
    from repro.pipeline.executor import PipelineExecutor

    class Counting(OE.Observer):
        def __init__(self):
            self.n = 0
            self.dispatched = 0

        def span(self, span):
            self.n += 1

        def dispatch(self, stage, ins):
            self.dispatched += 1

    cfg, params, batch = exec_setup
    obs = Counting()
    spec = P.ScheduleSpec("1f1b", 4, 8)
    ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
    res = ex.step(params, batch, observer=obs)
    assert res.events is None
    sch = P.compile_plan(spec.with_m(8))
    total = sum(len(s) for s in sch.streams.values())
    assert obs.dispatched == total
    assert obs.n == total          # compute spans; 1f1b moves nothing
