"""Differential schedule-fuzz harness.

Property-based over random *valid* ``ScheduleSpec``s — all kinds x
residency x cap x v x overlap depth, drawn through the hypothesis
strategies (the deterministic stub in ``_hypothesis_stub`` when the real
package is absent, so failures reproduce run-to-run):

  (a) executor loss/grads are bit-identical to the unmanaged execution
      of the same schedule family (residency moves must never change
      what is computed) and match the non-pipelined single-device
      reference to fp32 tolerance;
  (b) simulator makespan respects the ideal pipeline lower bound, is
      invariant under greedy vs round-robin engine order for every
      single-issuer-channel spec (all built-in policies at default
      caps), and is monotone non-increasing in overlap depth;
  (c) executor ``peak_bytes``/``bytes_moved`` agree with
      ``memory_model``'s per-stage accounting.

Failing specs are greedily *shrunk* (m, p, v, depth, cap toward
minimal while the property still fails) and reported as spec JSON —
also appended to ``fuzz_failures.json`` (``REPRO_FUZZ_ARTIFACT``) so CI
can upload the counterexample as an artifact.

Example counts are env-tunable (``scripts/check.sh`` pins them):
``REPRO_FUZZ_EXAMPLES`` for the cheap simulator properties (default
200), ``REPRO_FUZZ_EXEC_EXAMPLES`` for the jax-compiling executor
properties (default 3 — scripts/check.sh's dedicated harness step pins
6). The executor properties are also ``slow``-MARKED: each example
jit-compiles a real pipeline step, so a plain ``pytest -m 'not slow'``
sweep skips them and the harness step (or ``-m slow``) owns them.
"""
import dataclasses
import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import schedule as S
from repro.core import simulator as SIM
from repro.core.notation import Notation
from repro.memory import policy as respol
from repro.transfer.channel import channel_key

FUZZ_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "200"))
FUZZ_EXEC_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXEC_EXAMPLES", "3"))
ARTIFACT = os.environ.get("REPRO_FUZZ_ARTIFACT", "fuzz_failures.json")

KINDS = ("gpipe", "1f1b", "bpipe", "1f1b_interleaved", "bpipe_interleaved")
RESIDENCIES = ("none", "host_offload", "selective_recompute")


# ---------------------------------------------------------------------------
# Spec strategy: every draw is a structurally valid ScheduleSpec
# ---------------------------------------------------------------------------
def build_spec(kind_i: int, p: int, m_mult: int, v: int, res_i: int,
               cap_delta: int, depth: int,
               seq_chunks: int = 1) -> P.ScheduleSpec:
    kind = KINDS[kind_i % len(KINDS)]
    entry = S.SCHEDULES[kind]
    if entry.interleaved:
        v = max(2, v)
        m = p * max(1, m_mult)        # m % p == 0
    else:
        v = 1
        m = max(1, m_mult * 2)
    if not entry.sliced:
        seq_chunks = 1                # the spec would normalize anyway
    if entry.balanced:
        res = "none"                   # normalizes to bpipe_swap
        default, roof = entry.default_cap(p, v), entry.cap_roof(p, m, v)
    else:
        res = RESIDENCIES[res_i % len(RESIDENCIES)]
        pol = respol.POLICIES[res]
        default = pol.default_cap(p, v) if pol.active else None
        roof = pol.cap_roof(p, m, v) if pol.active else None
    cap = None
    if default is not None and cap_delta:
        # sliced defaults widen by the extra warmup slices; keep the
        # delta centered there so -1 still bites
        default += seq_chunks - 1
        roof += seq_chunks - 1
        cap = min(max(default + cap_delta, 2), max(roof, 2))
        if cap == default:
            cap = None
    return P.ScheduleSpec(kind, p, m, v=v, cap=cap, residency=res,
                          depth=depth, seq_chunks=seq_chunks)


spec_strategy = st.tuples(
    st.integers(0, len(KINDS) - 1),   # kind
    st.integers(2, 6),                # p
    st.integers(1, 3),                # m multiplier
    st.integers(2, 3),                # v (interleaved kinds)
    st.integers(0, len(RESIDENCIES) - 1),
    st.integers(-1, 1),               # cap delta around the default
    st.integers(1, 3),                # overlap depth
    st.sampled_from([1, 1, 2, 4]),    # seq_chunks (sliced kinds)
).map(lambda t: build_spec(*t))

cost_strategy = st.floats(0.0, 4.0)   # evict_bytes (bandwidths fixed at 1)


def _report(spec: P.ScheduleSpec, prop: str, detail: str) -> str:
    """Persist the failing spec for the CI artifact and build the
    assertion message (the spec JSON *is* the repro recipe)."""
    rec = {"property": prop, "spec": spec.to_dict(), "detail": detail}
    try:
        existing = []
        if os.path.exists(ARTIFACT):
            with open(ARTIFACT) as f:
                existing = json.load(f)
        existing.append(rec)
        with open(ARTIFACT, "w") as f:
            json.dump(existing, f, indent=1)
    except OSError:
        pass
    return f"[{prop}] failing spec {json.dumps(spec.to_dict())}: {detail}"


def shrink_spec(spec: P.ScheduleSpec, fails) -> P.ScheduleSpec:
    """Greedy shrink: repeatedly try the reductions (smaller m, p, v,
    depth; drop the cap override) and keep any that still fails, until
    a fixpoint — the counterexample reported is minimal under these
    moves."""
    def candidates(s):
        if s.m > s.p:
            yield dataclasses.replace(s, m=max(s.p, s.m // 2))
        if not s.interleaved and s.m > 1:
            yield dataclasses.replace(s, m=s.m - 1)
        if s.p > 2:
            p2 = s.p // 2 if s.p % 2 == 0 else s.p - 1
            m2 = s.m if not s.interleaved else (s.m // s.p) * p2
            try:
                yield P.ScheduleSpec(s.kind, p2, max(m2, p2), v=s.v,
                                     cap=None, residency=s.residency,
                                     depth=s.depth)
            except ValueError:
                pass
        if s.v > 2 and s.interleaved:
            yield dataclasses.replace(s, v=s.v - 1)
        if s.seq_chunks > 1:
            yield dataclasses.replace(s, seq_chunks=s.seq_chunks // 2)
        if s.depth > 1:
            yield dataclasses.replace(s, depth=s.depth - 1)
        if s.cap is not None:
            yield dataclasses.replace(s, cap=None)

    for _ in range(16):
        for cand in candidates(spec):
            try:
                if fails(cand):
                    spec = cand
                    break
            except Exception:      # noqa: BLE001 — a crash also "fails"
                spec = cand
                break
        else:
            return spec
    return spec


def _compiles(spec: P.ScheduleSpec) -> bool:
    """Tight sampled caps can be unbalanceable at some (p, m, v); those
    are invalid points of the space (the planner prunes them), not
    counterexamples."""
    try:
        P.compile_plan(spec)
        return True
    except (AssertionError, IndexError, ValueError):
        return False


def _issuers_per_channel(sch) -> dict:
    out = {}
    for i, stream in sch.streams.items():
        for x in stream:
            if x.is_wait:
                continue
            pol = respol.RELEASE_OPS.get(x.op) or respol.RESTORE_OPS.get(x.op)
            if pol is None:
                continue
            key = channel_key(pol.mechanism, i, sch.partner.get(i),
                              x.op in respol.RELEASE_OPS)
            if key is not None:
                out.setdefault(key, set()).add(i)
    return out


# ---------------------------------------------------------------------------
# (b) simulator: ideal bound, engine-order invariance, depth monotone
# ---------------------------------------------------------------------------
def _sim(spec, evict_bytes, greedy=True):
    return SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=1.0, Tb=2.0, evict_bytes=evict_bytes,
        pair_bw=1.0, d2h_bw=1.0, h2d_bw=1.0), greedy=greedy)


@given(spec_strategy, cost_strategy)
@settings(max_examples=FUZZ_EXAMPLES, deadline=None)
def test_simulator_bound_order_and_depth(spec, evict_bytes):
    if not _compiles(spec):
        return

    def violates(s):
        r = _sim(s, evict_bytes)
        # interleaving shrinks the fill/drain ramp by v, slicing by c
        # (per-slice F/B cost Tf/c, Tb/c) — v and c never both exceed 1
        ramp = (s.p - 1) / (s.v * s.seq_chunks)
        ideal = (s.m + ramp) * 3.0        # (m + ramp)(Tf + Tb)
        if r.makespan < ideal - 1e-9:
            return "makespan below the ideal pipeline bound " \
                f"({r.makespan} < {ideal})"
        if r.makespan < max(r.busy) - 1e-9:
            return "makespan below a stage's own busy time"
        if r.queue_peak > s.depth:
            return (f"channel occupancy {r.queue_peak} exceeds depth "
                    f"{s.depth}")
        sch = P.compile_plan(s)
        single = all(len(v_) == 1 for v_ in _issuers_per_channel(sch)
                     .values())
        if single:
            rr = _sim(s, evict_bytes, greedy=False)
            if rr.makespan != r.makespan or rr.timeline != r.timeline:
                return (f"engine-order variant: greedy {r.makespan} != "
                        f"round-robin {rr.makespan}")
        if s.policy.moves_data or s.balanced:
            deeper = _sim(dataclasses.replace(s, depth=s.depth + 1),
                          evict_bytes)
            if deeper.makespan > r.makespan + 1e-9:
                return (f"deeper overlap hurt: depth {s.depth} -> "
                        f"{r.makespan}, depth {s.depth + 1} -> "
                        f"{deeper.makespan}")
        return None

    why = violates(spec)
    if why is not None:
        small = shrink_spec(spec, lambda s: _compiles(s)
                            and violates(s) is not None)
        raise AssertionError(_report(small, "simulator", violates(small)
                                     or why))


@given(spec_strategy)
@settings(max_examples=min(FUZZ_EXAMPLES, 60), deadline=None)
def test_compiled_plan_self_consistency(spec):
    """Structural invariants of the compiled IR, fuzzed: every move has
    matching ISSUE/WAIT halves, the collapsed view is move-balanced, and
    the per-stage counts agree with the accounting."""
    if not _compiles(spec):
        return
    sch = P.compile_plan(spec)
    for i, stream in sch.streams.items():
        issues = [x for x in stream if x.phase == P.ISSUE]
        waits = [x for x in stream if x.is_wait]
        assert len(issues) == len(waits), (spec.to_dict(), i)
        assert {x.done_key for x in issues} == {x.done_key for x in waits}
        rel = sum(1 for x in issues if x.op in respol.RELEASE_OPS)
        res_ = sum(1 for x in issues if x.op in respol.RESTORE_OPS)
        assert rel == sch.num_evictions[i] and res_ == sch.num_loads[i], \
            _report(spec, "plan", f"stage {i} move counts disagree")
        # restores follow their release in stream order
        seen = set()
        for x in stream:
            if x.is_wait:
                continue
            if x.op in respol.RELEASE_OPS:
                seen.add((x.mb, x.chunk))
            elif x.op in respol.RESTORE_OPS:
                assert (x.mb, x.chunk) in seen, \
                    _report(spec, "plan", f"orphan restore {x!r}")


# ---------------------------------------------------------------------------
# (a) + (c) executor: numerics and byte agreement
# ---------------------------------------------------------------------------
def _exec_specs():
    """Structurally valid specs a 4-layer model can execute (p*v <= 4,
    m=4): the full kind x residency x cap x depth cross section, plus
    the sequence-sliced variants (c divides the batch's seq=8; sliced
    runs stay at the default cap — the cap ladder is already covered
    unsliced and each extra executor spec is a jit compile)."""
    out = []
    for kind, p, v, c in (("gpipe", 2, 1, 1), ("1f1b", 4, 1, 1),
                          ("bpipe", 4, 1, 1),
                          ("1f1b_interleaved", 2, 2, 1),
                          ("bpipe_interleaved", 2, 2, 1),
                          ("gpipe", 2, 1, 2), ("1f1b", 4, 1, 2),
                          ("bpipe", 4, 1, 2), ("1f1b", 2, 1, 4)):
        entry = S.SCHEDULES[kind]
        residencies = ("none",) if entry.balanced else RESIDENCIES
        for res in residencies:
            pol = respol.POLICIES[res]
            managed = entry.balanced or pol.active
            if entry.balanced:
                default = entry.default_cap(p, v)
            elif pol.active:
                default = pol.default_cap(p, v)
            for cap_delta in (0, -1):
                if cap_delta and (not managed or c > 1):
                    continue
                cap = None if not cap_delta else max(default + cap_delta, 2)
                for depth in (1, 2):
                    try:
                        spec = P.ScheduleSpec(kind, p, 4, v=v, cap=cap,
                                              residency=res, depth=depth,
                                              seq_chunks=c)
                    except ValueError:
                        continue
                    if not _compiles(spec):
                        continue
                    if spec not in out:
                        out.append(spec)
    return out


_EXEC_CACHE = {}


def _exec_step(spec):
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.pipeline import PipelineExecutor
    if "setup" not in _EXEC_CACHE:
        cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                                  num_layers=4, dtype="float32")
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(7), (4, 9), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        ref_loss, _ = M.loss_fn(params, batch, cfg)
        _EXEC_CACHE["setup"] = (cfg, params, batch, float(ref_loss))
    cfg, params, batch, ref_loss = _EXEC_CACHE["setup"]
    if spec not in _EXEC_CACHE:
        ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
        _EXEC_CACHE[spec] = ex.step(params, batch)
    return _EXEC_CACHE[spec], ref_loss


def _unmanaged_twin(spec: P.ScheduleSpec) -> P.ScheduleSpec:
    kind = {"bpipe": "1f1b",
            "bpipe_interleaved": "1f1b_interleaved"}.get(spec.kind,
                                                         spec.kind)
    return P.ScheduleSpec(kind, spec.p, spec.m, v=spec.v,
                          seq_chunks=spec.seq_chunks)


@pytest.mark.slow
@given(st.sampled_from(_exec_specs()))
@settings(max_examples=FUZZ_EXEC_EXAMPLES, deadline=None)
def test_executor_differential_vs_unmanaged(spec):
    import jax
    import numpy as np
    r, ref_loss = _exec_step(spec)
    base, _ = _exec_step(_unmanaged_twin(spec))
    # fp32 contract vs the non-pipelined single-device reference
    assert abs(float(r.loss) - ref_loss) < 1e-5, \
        _report(spec, "executor", f"loss {float(r.loss)} != ref {ref_loss}")
    # bit-identical to the unmanaged execution: residency moves relocate
    # the stash, they must never change what is computed
    assert float(r.loss) == float(base.loss), \
        _report(spec, "executor", "loss != unmanaged twin")
    for a, b in zip(jax.tree.leaves(r.grads), jax.tree.leaves(base.grads)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(_report(spec, "executor",
                                         "grads != unmanaged twin"))


@pytest.mark.slow
@given(st.sampled_from([s for s in _exec_specs() if s.seq_chunks > 1]))
@settings(max_examples=min(FUZZ_EXEC_EXAMPLES, 4), deadline=None)
def test_executor_sliced_parity_vs_unchunked(spec):
    """A sliced schedule computes the SAME training step as its
    unchunked twin — same loss, same grads — to fp32 tolerance (exact
    bit-parity is not expected: slice-wise softmax/vjp re-associates
    reductions)."""
    import jax
    import numpy as np
    r, _ = _exec_step(spec)
    twin = _unmanaged_twin(spec)
    base, _ = _exec_step(P.ScheduleSpec(twin.kind, twin.p, twin.m,
                                        v=twin.v))
    assert abs(float(r.loss) - float(base.loss)) < 1e-5, \
        _report(spec, "executor-sliced",
                f"loss {float(r.loss)} != unchunked {float(base.loss)}")
    for a, b in zip(jax.tree.leaves(r.grads), jax.tree.leaves(base.grads)):
        if not np.allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                           atol=1e-5):
            raise AssertionError(_report(spec, "executor-sliced",
                                         "grads drift vs unchunked twin"))


@pytest.mark.slow
@given(st.sampled_from(_exec_specs()))
@settings(max_examples=FUZZ_EXEC_EXAMPLES, deadline=None)
def test_executor_bytes_agree_with_memory_model(spec):
    r, _ = _exec_step(spec)
    cfg, params, batch, _ = _EXEC_CACHE["setup"]
    seq = batch["tokens"].shape[1]
    n = Notation(a=cfg.num_heads, b=1, h=cfg.d_model, l=cfg.num_layers,
                 s=seq, v=cfg.vocab_size, B=4, p=spec.p, t=1)
    sch = P.compile_plan(spec)
    unit = MM.sliced_unit_bytes(n, "none", spec.v, spec.seq_chunks)
    mems = MM.per_stage_memory(n, "none", spec)
    for i in range(spec.p):
        if r.stats.peak_local[i] > sch.peak_stash[i] + 1:
            raise AssertionError(_report(
                spec, "memory", f"stage {i} live peak "
                f"{r.stats.peak_local[i]} > compiled {sch.peak_stash[i]}+1"))
        # the model's depth charge is an upper bound on the live bytes
        if r.stats.peak_bytes[i] > mems[i].act_bytes + unit + 1e-6:
            raise AssertionError(_report(
                spec, "memory", f"stage {i} peak bytes exceed the model"))
    want = MM.traffic_bytes(n, "none", spec)
    if abs(r.stats.bytes_moved - want) > 1e-6 * max(want, 1.0):
        raise AssertionError(_report(
            spec, "memory",
            f"bytes_moved {r.stats.bytes_moved} != model {want}"))
    assert r.stats.transfers_inflight_peak <= spec.depth, \
        _report(spec, "memory", "in-flight transfers exceed the depth cap")


@pytest.mark.slow
@given(st.sampled_from(_exec_specs()))
@settings(max_examples=min(FUZZ_EXEC_EXAMPLES, 4), deadline=None)
def test_executor_and_simulator_emit_same_instruction_set(spec):
    """Observability census invariant (docs/observability.md): the
    simulator's and the real executor's event streams for the SAME spec
    contain the same instruction set — every key the model prices is
    executed and vice versa (timing and dispatch order may differ;
    ``obs.compare`` quantifies those separately)."""
    from repro.obs.events import Recorder
    key = (spec, "events")
    if key not in _EXEC_CACHE:
        from repro.pipeline import PipelineExecutor
        _exec_step(spec)                  # ensures the shared setup
        cfg, params, batch, _ = _EXEC_CACHE["setup"]
        ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
        _EXEC_CACHE[key] = ex.step(params, batch, trace=True).events
    real_keys = {s.key for s in _EXEC_CACHE[key] if s.track == "compute"}
    rec = Recorder()
    SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0, evict_bytes=1.0,
                               pair_bw=1.0, d2h_bw=1.0, h2d_bw=1.0),
                 observer=rec)
    if rec.keys() != real_keys:
        diff = sorted(rec.keys() ^ real_keys)
        raise AssertionError(_report(
            spec, "observability",
            f"sim/executor instruction sets differ on {len(diff)} keys: "
            f"{diff[:6]}"))
