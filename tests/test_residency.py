"""The activation-residency subsystem (``repro.memory``): spec dimension
validation, the shared spill rewrite, registry-driven op-set extension
(dep edges + accounting + simulator pricing + engine round-robin /
deadlock paths), real executor numerics for host_offload /
selective_recompute, executor-vs-memory-model byte agreement, and the
planner's joint (kind, residency, cap) search."""
import dataclasses
import json

import pytest

from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import schedule as S
from repro.core import simulator as SIM
from repro.core.notation import Notation
from repro.core.schedule import (B, DROP, EVICT, F, FETCH, LOAD, OFFLOAD,
                                 RECOMPUTE)
from repro.memory import policy as respol
from repro.memory.store import ActivationStore

RESIDENCIES = ("host_offload", "selective_recompute")
OPS = {"host_offload": (OFFLOAD, FETCH),
       "selective_recompute": (DROP, RECOMPUTE)}


# ---------------------------------------------------------------------------
# ScheduleSpec: residency as a validated, normalized dimension
# ---------------------------------------------------------------------------
def test_residency_validation_and_normalization():
    with pytest.raises(ValueError, match="unknown residency"):
        P.ScheduleSpec("1f1b", 4, 8, residency="nvme_offload")
    # balanced kinds embed the swap: normalize, reject contradictions
    assert P.ScheduleSpec("bpipe", 4, 8).residency == "bpipe_swap"
    assert P.ScheduleSpec("bpipe", 4, 8, residency="bpipe_swap") \
        == P.ScheduleSpec("bpipe", 4, 8)
    with pytest.raises(ValueError, match="embeds the partner swap"):
        P.ScheduleSpec("bpipe", 4, 8, residency="host_offload")
    with pytest.raises(ValueError, match="built-in mechanism"):
        P.ScheduleSpec("1f1b", 4, 8, residency="bpipe_swap")
    # cap: active residency policies cap plain kinds; default collapses
    spec = P.ScheduleSpec("1f1b", 4, 8, residency="host_offload")
    assert spec.cap is None
    assert spec.resolved_cap == respol.residency_cap(4, 1) == S.bpipe_cap(4)
    assert P.ScheduleSpec("1f1b", 4, 8, residency="host_offload",
                          cap=S.bpipe_cap(4)) == spec
    with pytest.raises(ValueError, match="cap must be >= 2"):
        P.ScheduleSpec("1f1b", 4, 8, residency="selective_recompute", cap=1)
    # no residency management -> no cap
    assert P.ScheduleSpec("1f1b", 4, 8, cap=7).cap is None
    assert "res=host_offload" in spec.label()


def test_spec_dict_round_trip_rejects_unknown_keys():
    spec = P.ScheduleSpec("1f1b_interleaved", 4, 8, v=2,
                          residency="selective_recompute", cap=9)
    d = json.loads(json.dumps(spec.to_dict()))
    assert P.ScheduleSpec.from_dict(d) == spec
    # old dicts without the residency key still load (default "none")
    legacy = {"kind": "bpipe", "p": 4, "m": 8, "v": 1, "cap": None}
    assert P.ScheduleSpec.from_dict(legacy) == P.ScheduleSpec("bpipe", 4, 8)
    with pytest.raises(ValueError, match="unknown ScheduleSpec keys"):
        P.ScheduleSpec.from_dict({**d, "residencyy": "none"})
    with pytest.raises(ValueError, match="residencyy"):
        P.ScheduleSpec.from_dict({**d, "residencyy": "none"})


# ---------------------------------------------------------------------------
# One spill discipline: the new policies mirror bpipe's decisions exactly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("residency", RESIDENCIES)
@pytest.mark.parametrize("kind,v", [("1f1b", 1), ("1f1b_interleaved", 2)])
def test_rewrite_mirrors_bpipe_spill_positions(kind, v, residency):
    """Same base schedule + same cap -> the release/restore ops land at
    exactly the positions bpipe's EVICT/LOAD land; only the op names
    (the mechanism) differ."""
    twin = {"1f1b": "bpipe", "1f1b_interleaved": "bpipe_interleaved"}[kind]
    p, m = 4, 8
    rel, res = OPS[residency]
    sub = {EVICT: rel, LOAD: res}
    bp = P.compile_plan(P.ScheduleSpec(twin, p, m, v=v)).instr_streams()
    got = P.compile_plan(
        P.ScheduleSpec(kind, p, m, v=v, residency=residency)).instr_streams()
    for i in range(p):
        want = [S.Instr(sub.get(x.op, x.op), x.mb, x.chunk) for x in bp[i]]
        assert got[i] == want


@pytest.mark.parametrize("residency", RESIDENCIES)
def test_compiled_accounting_peaks_and_spills(residency):
    spec = P.ScheduleSpec("1f1b", 4, 8, residency=residency)
    sch = P.compile_plan(spec)
    cap = spec.resolved_cap
    # the local stash honors the cap on every stage
    assert all(pk <= cap for pk in sch.peak_stash.values())
    # early stages spill (they hold the 1F1B imbalance), late ones don't
    assert sch.peak_spilled[0] > 0 and sch.peak_spilled[3] == 0
    # release waits on the unit's F; restore on its release
    rel, res = OPS[residency]
    r0 = next(x for x in sch.streams[0] if x.op == rel)
    assert r0.dep == (F, 0, r0.mb, r0.chunk, 0)
    s0 = next(x for x in sch.streams[0] if x.op == res)
    assert s0.dep == (rel, 0, s0.mb, s0.chunk, 0)
    # moves = release + restore count of the stream actually built
    assert P.num_moves(spec) == sum(sch.num_evictions.values()) \
        + sum(sch.num_loads.values()) > 0
    # partner map is the swap's business only
    assert sch.partner == {}


# ---------------------------------------------------------------------------
# Satellite: engine round-robin merge + deadlock paths over extended ops
# ---------------------------------------------------------------------------
def test_round_robin_accounting_drains_extended_ops():
    for residency in RESIDENCIES:
        spec = P.ScheduleSpec("1f1b", 4, 8, residency=residency)
        streams = P.compile_plan(spec).streams
        # greedy=False round-robin merge (what _account counts over)
        traces, spill_traces, counts = P._account(streams, 4)
        assert set(counts.values()) == {0}          # every stream drains
        assert max(spill_traces[0]) == \
            P.compile_plan(spec).peak_spilled[0]
        # legacy two-tuple view agrees
        t2, c2 = P.stash_accounting(streams, 4)
        assert t2 == traces and c2 == counts


def test_malformed_stream_deadlocks_with_message():
    # FETCH without a prior OFFLOAD: its dependency can never complete
    bad = {0: P._plan_stream(
        P.ScheduleSpec("1f1b", 1, 1, residency="host_offload"), 0,
        [S.Instr(F, 0), S.Instr(FETCH, 0), S.Instr(B, 0)])}
    with pytest.raises(P.ScheduleDeadlock, match="FETCH0@0"):
        P.run(bad, _sim_handlers(bad))
    # RECOMPUTE without DROP deadlocks the same way
    rec = {0: P._plan_stream(
        P.ScheduleSpec("1f1b", 1, 1, residency="selective_recompute"), 0,
        [S.Instr(F, 0), S.Instr(RECOMPUTE, 0), S.Instr(B, 0)])}
    with pytest.raises(P.ScheduleDeadlock) as e:
        P.run(rec, _sim_handlers(rec))
    assert "RECOMPUTE0@0" in str(e.value) and isinstance(
        e.value, RuntimeError)


def _sim_handlers(streams):
    """Minimal dataflow handlers: done-set semantics like the simulator."""
    done = set()

    def handler(i, ins):
        if ins.dep is not None and ins.dep not in done:
            return P.BLOCKED
        done.add(ins.done_key)
    return {op: handler for op in (F, B, EVICT, LOAD, OFFLOAD, FETCH,
                                   DROP, RECOMPUTE)}


# ---------------------------------------------------------------------------
# Simulator pricing by mechanism
# ---------------------------------------------------------------------------
def test_offload_priced_on_host_link():
    base = SIM.simulate(SIM.SimConfig(
        spec=P.ScheduleSpec("1f1b", 4, 8), Tf=1.0, Tb=2.0))
    spec = P.ScheduleSpec("1f1b", 4, 8, residency="host_offload")
    fast = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0))
    # infinite host bandwidth: offload is free, makespan identical
    assert fast.makespan == base.makespan and fast.load_stall == 0.0
    slow = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=1.0, Tb=2.0, evict_bytes=4.0, d2h_bw=1.0, h2d_bw=1.0))
    assert slow.load_stall > 0.0 and slow.makespan > base.makespan
    assert slow.move_time > 0.0
    # the pair link is NOT involved: pair_bw cannot slow offload down
    pair_slow = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=1.0, Tb=2.0, evict_bytes=4.0, pair_bw=1e-9))
    assert pair_slow.makespan == base.makespan


def test_recompute_priced_as_compute():
    base = SIM.simulate(SIM.SimConfig(
        spec=P.ScheduleSpec("1f1b", 4, 8), Tf=1.0, Tb=2.0))
    spec = P.ScheduleSpec("1f1b", 4, 8, residency="selective_recompute")
    rec = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0))
    n_rec = P.compile_plan(spec).num_loads[0]
    # stage 0 re-runs n_rec chunk forwards ON the compute frontier
    assert rec.busy[0] == pytest.approx(base.busy[0] + n_rec * 1.0)
    assert rec.makespan > base.makespan
    # bandwidth knobs cannot touch it: FLOPs, not bytes
    rec2 = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=1.0, Tb=2.0, evict_bytes=100.0,
        d2h_bw=1e-9, h2d_bw=1e-9, pair_bw=1e-9))
    assert rec2.makespan == rec.makespan


def test_legacy_simconfig_residency_knob():
    legacy = SIM.SimConfig(p=4, m=8, Tf=1.0, Tb=2.0, kind="1f1b",
                           residency="host_offload")
    spec = P.ScheduleSpec("1f1b", 4, 8, residency="host_offload")
    assert legacy.to_spec() == spec
    a = SIM.simulate(legacy)
    b = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0))
    assert a.makespan == b.makespan and a.timeline == b.timeline
    # a cap override must survive the legacy-knob path: the policy is
    # what makes cap meaningful on a plain kind
    capped = SIM.SimConfig(p=4, m=8, Tf=1.0, Tb=2.0, kind="1f1b",
                           residency="host_offload", cap=4)
    assert capped.to_spec().resolved_cap == 4
    assert capped.to_spec() == P.ScheduleSpec("1f1b", 4, 8, cap=4,
                                              residency="host_offload")


# ---------------------------------------------------------------------------
# Memory model: per-policy byte accounting
# ---------------------------------------------------------------------------
def test_per_policy_byte_accounting():
    n = Notation(a=4, b=2, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
    att = "recompute"
    per_mb = MM.act_bytes_per_stage(n, att, 1)
    plain = MM.per_stage_memory(n, att, "1f1b")
    off = MM.per_stage_memory(
        n, att, P.ScheduleSpec("1f1b", 4, n.num_micro,
                               residency="host_offload"))
    rec = MM.per_stage_memory(
        n, att, P.ScheduleSpec("1f1b", 4, n.num_micro,
                               residency="selective_recompute"))
    # stage 0 spills under the cap: offload frees the full unit to host,
    # recompute retains the boundary input — strict ordering
    assert off[0].act_bytes < rec[0].act_bytes < plain[0].act_bytes
    assert off[0].host_bytes > 0 and rec[0].host_bytes == 0.0
    sch = P.compile_plan(P.ScheduleSpec("1f1b", 4, n.num_micro,
                                        residency="host_offload"))
    assert off[0].host_bytes == pytest.approx(
        sch.peak_spilled[0] * per_mb)
    boundary = 2.0 * n.s * n.b * n.h / n.t
    assert rec[0].act_bytes == pytest.approx(
        sch.peak_stash[0] * per_mb + sch.peak_spilled[0] * boundary)
    # traffic: offload moves bytes, recompute does not
    assert MM.traffic_bytes(
        n, att, P.ScheduleSpec("1f1b", 4, n.num_micro,
                               residency="host_offload")) > 0.0
    assert MM.traffic_bytes(
        n, att, P.ScheduleSpec("1f1b", 4, n.num_micro,
                               residency="selective_recompute")) == 0.0


# ---------------------------------------------------------------------------
# Executor: real numerics for both policies, byte agreement regression
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def exec_setup():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=8, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (8, 9), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ref_loss, _ = M.loss_fn(params, batch, cfg)
    return cfg, params, batch, ref_loss


@pytest.mark.parametrize("residency", RESIDENCIES)
def test_executor_residency_matches_loss_fn(exec_setup, residency):
    import jax
    import numpy as np
    from repro.pipeline import PipelineExecutor
    cfg, params, batch, ref_loss = exec_setup
    base = PipelineExecutor(cfg, spec=P.ScheduleSpec("1f1b", 4, 8),
                            micro_batch=1)
    r0 = base.step(params, batch)
    ex = PipelineExecutor(
        cfg, spec=P.ScheduleSpec("1f1b", 4, 8, residency=residency),
        micro_batch=1)
    r1 = ex.step(params, batch)
    # fp32 contract vs the non-pipelined reference...
    assert abs(float(r1.loss - ref_loss)) < 1e-5
    # ...and bit-identical to the resident execution: the offload round
    # trip moves arrays losslessly, the re-forward is deterministic
    assert float(r1.loss) == float(r0.loss)
    for a, b in zip(jax.tree.leaves(r0.grads), jax.tree.leaves(r1.grads)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    s = r1.stats
    if residency == "host_offload":
        assert s.offloads == s.fetches > 0 and s.drops == 0
        assert s.bytes_moved > 0 and max(s.host_peak_bytes.values()) > 0
    else:
        assert s.drops == s.recomputes > 0 and s.offloads == 0
        assert s.bytes_moved == 0.0
    # the residency cap really bounds the live store
    cap = P.ScheduleSpec("1f1b", 4, 8, residency=residency).resolved_cap
    assert max(s.peak_local.values()) <= cap


@pytest.mark.parametrize("kind,v,residency", [
    ("1f1b_interleaved", 2, "none"),
    ("bpipe_interleaved", 2, "none"),
    ("1f1b_interleaved", 2, "host_offload"),
])
def test_executor_bytes_agree_with_memory_model(exec_setup, kind, v,
                                                residency):
    """Satellite regression: the store charges the SAME v-chunk unit
    weighting as memory_model.act_bytes_per_stage, so executor-reported
    peak_bytes / bytes_moved agree with the model's per-stage numbers
    for interleaved kinds (peaks compared where the live store reaches
    the compiled bound)."""
    from repro.pipeline import PipelineExecutor
    cfg, params, batch, _ = exec_setup
    spec = P.ScheduleSpec(kind, 4, 8, v=v, residency=residency)
    ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
    r = ex.step(params, batch)
    seq = batch["tokens"].shape[1]
    n = Notation(a=cfg.num_heads, b=1, h=cfg.d_model, l=cfg.num_layers,
                 s=seq, v=cfg.vocab_size, B=8, p=4, t=1)
    unit = MM.act_bytes_per_stage(n, "none", v)
    mems = MM.per_stage_memory(n, "none", spec)
    sch = P.compile_plan(spec)
    retained = spec.policy.retained_bytes(n, "none", v)
    for i in range(4):
        # per-chunk weighting: live peak bytes = live peak units x the
        # model's unit bytes (+ retained bytes of spilled units)
        assert r.stats.peak_bytes[i] <= mems[i].act_bytes + unit
        if r.stats.peak_local[i] == sch.peak_stash[i]:
            assert r.stats.peak_bytes[i] == pytest.approx(
                mems[i].act_bytes - sch.peak_spilled.get(i, 0) * retained,
                rel=1e-6, abs=unit * 0.51)
    # traffic agreement is exact: moves x unit bytes
    assert r.stats.bytes_moved == pytest.approx(
        MM.traffic_bytes(n, "none", spec))


def test_store_per_chunk_weighting():
    """The store accepts per-(owner, chunk) weights and charges moves /
    peaks with them (the hook non-uniform layer assignments plug into)."""
    w = {(0, 0): 10.0, (0, 1): 1.0, (1, 0): 5.0, (1, 1): 5.0}
    st = ActivationStore(2, lambda stage, chunk: w[(stage, chunk)])
    st.put(0, 0, "a", chunk=0)
    st.put(0, 0, "b", chunk=1)
    assert st.peak_bytes[0] == 11.0
    st.evict(0, 0, partner=1, chunk=0)      # 10 bytes move to stage 1
    assert st.bytes_moved == 10.0
    assert st.cur_bytes[0] == 1.0 and st.cur_bytes[1] == 10.0
    st.load(0, 0, partner=1, chunk=0)
    assert st.bytes_moved == 20.0 and st.peak_bytes[0] == 11.0


# ---------------------------------------------------------------------------
# Planner: residency searched jointly with (kind, v, b, m, cap)
# ---------------------------------------------------------------------------
def _notation():
    return Notation(a=4, b=1, h=256, l=16, s=128, v=512, B=16, p=4, t=1)


def test_search_space_enumerates_residency_with_cap_ladder():
    from repro.planner import SearchSpace
    from repro.planner.space import enumerate_candidates
    n = _notation()
    cands = list(enumerate_candidates(
        n, SearchSpace(kinds=("1f1b", "bpipe"), attentions=("recompute",),
                       vs=(2,))))
    res = {c.residency for c in cands}
    assert res == {"none", "bpipe_swap", "host_offload",
                   "selective_recompute"}
    # active residency opens its own cap ladder on the PLAIN kind
    offload_caps = {c.cap for c in cands
                    if c.residency == "host_offload" and c.kind == "1f1b"}
    assert len(offload_caps) > 1
    # every candidate spec-compiles
    for c in cands:
        P.compile_plan(c.spec(n.p))


def test_managed_plans_face_break_even_and_ties_prefer_less_traffic():
    from repro.planner import AnalyticCostModel, SearchSpace
    from repro.planner import rank as R
    from repro.planner.space import enumerate_candidates
    n = _notation()
    hbm = 1.2 * MM.max_stage_bytes(n, "recompute", "1f1b")
    ranked = R.rank(n, enumerate_candidates(
        n, SearchSpace(vs=(2,), attentions=("recompute",))),
        AnalyticCostModel(), hbm, workspace=0.0)
    managed = [rp for rp in ranked
               if rp.cand.residency not in ("none",) and rp.ok]
    assert managed, "no managed plan survived"
    # every surviving managed plan carries the break-even bar vs the
    # unmanaged 1f1b baseline (or the arm has no such baseline)
    for rp in managed:
        assert rp.required_gain > 0 or "baseline" in rp.note
    # equal-MFU ties resolve toward less residency move time
    for a, b_ in zip(ranked, ranked[1:]):
        if a.verdict == b_.verdict == "ok" and a.mfu == b_.mfu:
            assert a.move_time <= b_.move_time


def test_custom_policy_registers_end_to_end(exec_setup):
    """Registering a ResidencyPolicy is the ONE step: its ops compile
    (dep edges + accounting), simulate (priced by mechanism), EXECUTE
    (handlers derived from the registry), and the spec dimension
    accepts it."""
    from repro.pipeline import PipelineExecutor
    pol = respol.ResidencyPolicy(
        "nvme_offload", "NVME_OUT", "NVME_IN", mechanism="host",
        default_cap=respol.residency_cap,
        cap_roof=respol.residency_cap_roof)
    respol.register(pol)
    try:
        with pytest.raises(ValueError, match="already registered"):
            respol.register(pol)
        spec = P.ScheduleSpec("1f1b", 4, 8, residency="nvme_offload")
        sch = P.compile_plan(spec)
        assert any(x.op == "NVME_OUT" for x in sch.streams[0])
        twin = P.compile_plan(
            P.ScheduleSpec("1f1b", 4, 8, residency="host_offload"))
        assert sch.peak_stash == twin.peak_stash
        res = SIM.simulate(SIM.SimConfig(
            spec=spec, Tf=1.0, Tb=2.0, evict_bytes=4.0,
            d2h_bw=1.0, h2d_bw=1.0))
        ref = SIM.simulate(SIM.SimConfig(
            spec=P.ScheduleSpec("1f1b", 4, 8, residency="host_offload"),
            Tf=1.0, Tb=2.0, evict_bytes=4.0, d2h_bw=1.0, h2d_bw=1.0))
        assert res.makespan == ref.makespan
        # executable with no interpreter edits: handlers come from the
        # registry, not a hard-coded op list
        cfg, params, batch, ref_loss = exec_setup
        ex = PipelineExecutor(cfg, spec=spec, micro_batch=1)
        r = ex.step(params, batch)
        assert abs(float(r.loss - ref_loss)) < 1e-5
        assert r.stats.offloads == r.stats.fetches > 0
    finally:
        respol.unregister("nvme_offload")
    with pytest.raises(ValueError, match="unknown residency"):
        P.ScheduleSpec("1f1b", 4, 8, residency="nvme_offload")


def test_fit_trace_tolerates_residency_ops():
    from repro.obs import events as OE
    from repro.planner import calibrate
    events = [OE.make(F, 0, 0, start=0.0, end=1.0),
              OE.make(OFFLOAD, 0, 0, start=1.0, end=1.5),
              OE.make(RECOMPUTE, 0, 0, start=1.5, end=2.0),
              OE.make(B, 0, 0, start=2.0, end=4.0)]
    fit = calibrate.fit_trace(events)
    assert (fit.Tf, fit.Tb) == (1.0, 2.0) and fit.samples == 4


def test_policy_op_collision_rejected():
    with pytest.raises(ValueError, match="collide"):
        respol.register(respol.ResidencyPolicy(
            "evil", OFFLOAD, "OTHER", mechanism="host",
            default_cap=respol.residency_cap,
            cap_roof=respol.residency_cap_roof))
    with pytest.raises(ValueError, match="need release_op"):
        respol.ResidencyPolicy("half", "REL", None, mechanism="host")
