"""Vocabulary accounting + the vocab_parallel plan dimension.

The bugfix this pins: embedding/LM-head param+grad+optimizer state and
the fp32 logits tensor are charged to the stages that HOLD them (stage 0
/ stage p-1), not uniformly spread over the pipeline — and the planner
can then scatter that spike over boundary stages (``vocab_parallel``,
docs/memory.md "Vocab accounting") and have the split priced end to end
(memory model, simulator, branch-and-bound bound).
"""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core import memory_model as MM
from repro.core import plan as P
from repro.core import simulator as SIM
from repro.core.notation import A100_HBM_BYTES, Notation, from_model
from repro.planner import (AnalyticCostModel, SearchSpace, cost_model_for,
                           plan_config, recommend)
from repro.planner import rank as R
from repro.planner import space as SP
from repro.sharding import rules


def _paper_shape(name):
    cfg = get_config(name)
    return cfg, from_model(cfg, b=1, s=2048, B=128, p=8, t=4)


# ---------------------------------------------------------------------------
# Memory accounting: the spike sits on the boundary stages
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["qwen3-14b", "llama-65b"])
def test_boundary_stages_carry_the_vocab_spike(name):
    cfg, n = _paper_shape(name)
    mems = MM.per_stage_memory(n, "recompute", "1f1b", cfg)
    mid = n.p // 2
    # middle stages carry blocks only
    assert mems[mid].vocab_bytes == 0.0
    # stage 0: the embedding table's full optimizer state
    table = cfg.vocab_size * cfg.d_model / n.t
    assert mems[0].vocab_bytes == pytest.approx(table * MM.BYTES_PER_PARAM)
    # stage p-1: the (untied) head state plus the fp32 logits
    assert mems[-1].vocab_bytes == pytest.approx(
        table * MM.BYTES_PER_PARAM + MM.logits_bytes(n))
    # and the spike is real memory: stage 0 (which already stashes the
    # most under 1F1B) now also carries the table's optimizer state
    assert mems[0].total > mems[mid].total
    # total includes the vocab share — the field isn't decorative
    assert mems[0].total == pytest.approx(
        mems[0].act_bytes + mems[0].param_bytes + mems[0].vocab_bytes)


def test_qwen3_vocab_spike_dwarfs_llama_control():
    """151k-vocab qwen3 vs the paper's 32k-vocab llama-65b: relative to
    a middle stage's bill, the spike only bites on the big vocab."""
    ratios = {}
    for name in ("qwen3-14b", "llama-65b"):
        cfg, n = _paper_shape(name)
        mems = MM.per_stage_memory(n, "recompute", "1f1b", cfg)
        ratios[name] = mems[0].vocab_bytes / mems[n.p // 2].total
    assert ratios["qwen3-14b"] > 3 * ratios["llama-65b"]


def test_vocab_parallel_scatters_the_spike():
    cfg, n = _paper_shape("qwen3-14b")
    base = MM.vocab_bytes_per_stage(n, cfg, 1)
    for vp in (2, 4, 8):
        vb = MM.vocab_bytes_per_stage(n, cfg, vp)
        # conservation: scattering relocates state, never changes the sum
        assert sum(vb) == pytest.approx(sum(base))
        if 2 * vp <= n.p:
            # disjoint ranges: each participant holds 1/vp of its side
            assert vb[0] == pytest.approx(base[0] / vp)
            assert vb[-1] == pytest.approx(base[-1] / vp)
        if vp == n.p:
            # full overlap: a perfectly even spread
            for x in vb:
                assert x == pytest.approx(sum(base) / vp)
        # non-participants hold nothing (a middle gap exists while the
        # first-vp and last-vp ranges don't meet)
        if 2 * vp < n.p:
            assert vb[n.p // 2] == 0.0


def test_param_bytes_exclude_vocab_both_paths():
    """The fixed bug: blocks-only param bytes in the cfg path AND the
    GPT-like fallback — the vocab share moved to vocab_bytes_per_stage."""
    cfg, n = _paper_shape("qwen3-14b")
    pb = MM.param_bytes_per_stage(n, cfg)
    spread = cfg.param_count() / n.p / n.t * MM.BYTES_PER_PARAM
    assert pb < spread
    assert pb == pytest.approx(
        (cfg.param_count() - MM.vocab_param_count(n, cfg))
        / n.p / n.t * MM.BYTES_PER_PARAM)
    # fallback: 12lh^2 blocks only — no 2vh term hiding in there
    n2 = Notation(a=4, b=1, h=256, l=16, s=128, v=262_144, B=16, p=4, t=1)
    assert MM.param_bytes_per_stage(n2, None) == pytest.approx(
        12.0 * n2.l * n2.h**2 / (n2.p * n2.t) * MM.BYTES_PER_PARAM)
    assert MM.vocab_param_count(n2, None) == pytest.approx(2.0 * n2.v * n2.h)


def test_tied_table_charged_once_with_replica_head():
    """gemma2-9b ties its table: stage 0 owns the optimizer state, the
    last stage pays only the bf16 param+grad working copy."""
    cfg, n = _paper_shape("gemma2-9b")
    assert cfg.tie_embeddings
    vb = MM.vocab_bytes_per_stage(n, cfg, 1)
    table = cfg.vocab_size * cfg.d_model / n.t
    assert vb[0] == pytest.approx(table * MM.BYTES_PER_PARAM)
    assert vb[-1] == pytest.approx(
        table * MM.TIED_REPLICA_BYTES_PER_PARAM + MM.logits_bytes(n))
    # p == 1: one tensor, charged once, logits on top
    n1 = n.replace(p=1)
    vb1 = MM.vocab_bytes_per_stage(n1, cfg, 1)
    assert vb1 == [pytest.approx(table * MM.BYTES_PER_PARAM
                                 + MM.logits_bytes(n1))]


def test_vocab_collective_and_traffic_pricing():
    cfg, n = _paper_shape("qwen3-14b")
    assert MM.vocab_collective_bytes(n, 1) == 0.0
    vcb = MM.vocab_collective_bytes(n, 4)
    assert vcb == pytest.approx(2.0 * 3 / 4 * 2.0 * n.s * n.b * n.h / n.t)
    spec = P.ScheduleSpec("1f1b", n.p, n.num_micro)
    vspec = dataclasses.replace(spec, vocab_parallel=4)
    base = MM.traffic_bytes(n, "recompute", spec)
    assert MM.traffic_bytes(n, "recompute", vspec) \
        == pytest.approx(base + 4.0 * spec.m * vcb)


# ---------------------------------------------------------------------------
# ScheduleSpec: validation, label, round-trip, compile re-bind
# ---------------------------------------------------------------------------
def test_spec_vocab_parallel_validation():
    with pytest.raises(ValueError, match="vocab_parallel"):
        P.ScheduleSpec("1f1b", 4, 16, vocab_parallel=0)
    with pytest.raises(ValueError, match="vocab_parallel"):
        P.ScheduleSpec("1f1b", 4, 16, vocab_parallel=8)
    # p == 1: nothing to scatter over — normalized, not rejected
    assert P.ScheduleSpec("gpipe", 1, 4, vocab_parallel=1).vocab_parallel == 1


def test_spec_vocab_parallel_roundtrip_and_label():
    spec = P.ScheduleSpec("bpipe", 4, 16, vocab_parallel=4)
    assert "vp=4" in spec.label()
    assert "vp=" not in P.ScheduleSpec("bpipe", 4, 16).label()
    d = spec.to_dict()
    assert d["vocab_parallel"] == 4
    assert P.ScheduleSpec.from_dict(d) == spec
    bad = dict(d, vocap_parallel=2)
    with pytest.raises((TypeError, ValueError, KeyError)):
        P.ScheduleSpec.from_dict(bad)


def test_compile_rebinds_vocab_parallel_to_base_streams():
    """vocab_parallel is a pricing dimension: the compiled streams are
    the vp=1 base's, byte-identical dispatch."""
    spec = P.ScheduleSpec("1f1b", 4, 16, vocab_parallel=2)
    sch = P.compile_plan(spec)
    base = P.compile_plan(P.ScheduleSpec("1f1b", 4, 16))
    assert sch.streams is base.streams
    assert sch.spec.vocab_parallel == 2


# ---------------------------------------------------------------------------
# Simulator: boundary-stage collective pricing
# ---------------------------------------------------------------------------
def test_simulator_prices_vocab_collective_on_boundaries():
    spec = P.ScheduleSpec("1f1b", 4, 16)
    plain = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0))
    assert plain.vocab_time == 0.0
    priced = SIM.simulate(SIM.SimConfig(spec=spec, Tf=1.0, Tb=2.0,
                                        t_vocab=0.25))
    # every boundary-stage F and B pays: 2 stages * m * (F + B)
    assert priced.vocab_time == pytest.approx(2 * 16 * 2 * 0.25)
    assert priced.makespan > plain.makespan
    # middle stages' busy time is untouched; boundaries absorb the charge
    assert priced.busy[1] == pytest.approx(plain.busy[1])
    assert priced.busy[0] == pytest.approx(plain.busy[0] + 16 * 2 * 0.25)


def test_sim_config_for_injects_t_vocab():
    """The CLI's re-simulation path prices the collective exactly as
    rank did: t_vocab = collective bytes / link bw, 0 when unscattered."""
    n = Notation(a=4, b=1, h=256, l=16, s=128, v=262_144, B=16, p=4, t=1)
    cost = AnalyticCostModel()
    hbm = 1.5 * MM.max_stage_bytes(n, "recompute", "1f1b")
    ranked = R.rank(n, list(SP.enumerate_candidates(
        n, SearchSpace(vs=(2,), vocab_parallels=(1, 2)))),
        cost, hbm, workspace=0.0)
    by_vp = {}
    for rp in ranked:
        if rp.makespan > 0:
            by_vp.setdefault(rp.cand.vocab_parallel, rp)
    assert {1, 2} <= set(by_vp)
    assert R.sim_config_for(n, by_vp[1], cost).t_vocab == 0.0
    sc = R.sim_config_for(n, by_vp[2], cost)
    nb = n.replace(b=by_vp[2].cand.b)
    from repro.core.notation import NVLINK_BW
    assert sc.t_vocab == pytest.approx(
        MM.vocab_collective_bytes(nb, 2) / NVLINK_BW)


# ---------------------------------------------------------------------------
# Planner: the dimension is searched, bounded, and changes a verdict
# ---------------------------------------------------------------------------
def test_search_space_default_stays_unscattered():
    n = Notation(a=4, b=1, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
    cands = list(SP.enumerate_candidates(n, SearchSpace()))
    assert all(c.vocab_parallel == 1 for c in cands)
    opened = list(SP.enumerate_candidates(
        n, SearchSpace(vocab_parallels=(1, 2, 4, 8))))
    vps = {c.vocab_parallel for c in opened}
    assert vps == {1, 2, 4}  # 8 > p filtered out
    assert len(opened) == 3 * len(cands)


def test_vocab_parallel_turns_qwen3_feasible():
    """The acceptance bar: at 14 GiB the unscattered planner finds
    NOTHING for qwen3-14b (151k vocab), the vp ladder finds a plan."""
    cfg, n = _paper_shape("qwen3-14b")
    cost = cost_model_for(cfg)
    hbm = 14 * 2**30
    base = plan_config(n, cfg, hbm, cost=cost,
                       search=SearchSpace(attentions=("recompute",)))
    assert recommend(base, "recompute") is None
    opened = plan_config(
        n, cfg, hbm, cost=cost,
        search=SearchSpace(attentions=("recompute",),
                           vocab_parallels=(1, 2, 4, 8)))
    rp = recommend(opened, "recompute")
    assert rp is not None and rp.cand.vocab_parallel > 1
    assert "vp=" in rp.cand.label()


def test_llama_control_verdict_unchanged():
    """32k-vocab llama-65b at the paper's A100-80G: opening the vp
    ladder must NOT move the recommendation (Table 3 protection)."""
    cfg, n = _paper_shape("llama-65b")
    cost = cost_model_for(cfg)
    base = plan_config(n, cfg, A100_HBM_BYTES, cost=cost,
                       search=SearchSpace(attentions=("recompute",)))
    opened = plan_config(
        n, cfg, A100_HBM_BYTES, cost=cost,
        search=SearchSpace(attentions=("recompute",),
                           vocab_parallels=(1, 2, 4, 8)))
    b, o = recommend(base, "recompute"), recommend(opened, "recompute")
    assert b is not None and o is not None
    assert o.cand == b.cand
    assert o.cand.vocab_parallel == 1


def test_pruned_matches_exhaustive_with_vocab_dimension():
    """pruned == exhaustive still holds on a space that includes vp
    (the B&B bound's ``2 m t_vocab`` term is admissible)."""
    n = Notation(a=4, b=1, h=256, l=16, s=128, v=262_144, B=16, p=4, t=1)
    cost = AnalyticCostModel()
    hbm = 1.5 * MM.max_stage_bytes(n, "recompute", "1f1b")
    cands = list(SP.enumerate_candidates(
        n, SearchSpace(vs=(2,), vocab_parallels=(1, 2, 4))))
    fast = R.rank(n, cands, cost, hbm, workspace=0.0)
    full = R.rank(n, cands, cost, hbm, workspace=0.0, exhaustive=True)
    by_cand = {rp.cand: rp for rp in full}
    for arm in R.arms_of(full) + [None]:
        bf, bx = recommend(fast, arm), recommend(full, arm)
        assert (bf.cand if bf else None) == (bx.cand if bx else None)
    for rp in fast:
        if rp.makespan > 0:
            bound = R.mfu_upper_bound(n, rp.cand, cost)
            assert rp.mfu <= bound + 1e-12, (rp.cand, rp.mfu, bound)
            twin = by_cand[rp.cand]
            assert (rp.mfu, rp.makespan) == (twin.mfu, twin.makespan)


# ---------------------------------------------------------------------------
# Sharding: the stage-scatter layout
# ---------------------------------------------------------------------------
def test_vocab_shard_range_tiles_exactly():
    vocab, p = 151_936, 8
    for side, owners in (("embed", range(4)), ("head", range(4, 8))):
        spans = [rules.vocab_shard_range(i, p, 4, vocab, side)
                 for i in range(p)]
        held = [spans[i] for i in owners]
        # participating stages tile [0, vocab) in order, no gaps
        assert held[0][0] == 0 and held[-1][1] == vocab
        for (_, hi), (lo, _) in zip(held, held[1:]):
            assert hi == lo
        for i in range(p):
            if i not in owners:
                assert spans[i] == (0, 0)
    # vp=1: the owner stage holds everything
    assert rules.vocab_shard_range(0, p, 1, vocab, "embed") == (0, vocab)
    assert rules.vocab_shard_range(p - 1, p, 1, vocab, "head") == (0, vocab)
    assert rules.vocab_shard_range(0, p, 1, vocab, "head") == (0, 0)
    with pytest.raises(ValueError):
        rules.vocab_shard_range(0, p, 1, vocab, "logits")


def test_vocab_param_spec_moves_model_axis():
    from jax.sharding import PartitionSpec
    assert rules.vocab_param_spec("table") == PartitionSpec(rules.M, None)
    assert rules.vocab_param_spec("table", 4) == PartitionSpec(None, rules.M)
    assert rules.vocab_param_spec("unembed", 4) \
        == PartitionSpec(rules.M, None)
    with pytest.raises(KeyError):
        rules.vocab_param_spec("wq", 4)


# ---------------------------------------------------------------------------
# Satellite: reduced() keeps a decoupled head_dim's ratio
# ---------------------------------------------------------------------------
def test_reduced_preserves_decoupled_head_dim_ratio():
    cfg = get_config("gemma2-9b")  # head_dim 256 != 3584/16 = 224
    r = cfg.reduced()
    base = r.d_model // r.num_heads
    want = 2 * round(base * cfg.head_dim * cfg.num_heads
                     / cfg.d_model / 2)
    assert r.head_dim == want != base
    assert r.head_dim % 2 == 0  # RoPE splits the head in half
    # coupled families stay coupled
    q = get_config("qwen3-14b").reduced()
    assert q.head_dim == q.d_model // q.num_heads
