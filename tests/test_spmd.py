"""SPMD collective pipeline — runs in a subprocess with 8 fake devices
(the main test process must keep the single real CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.configs import get_config
    from repro.pipeline.spmd import init_pipeline_params, make_spmd_train_loss
    from repro.models.blocks import apply_layer
    from repro.models.layers import apply_norm, embed, unembed

    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=4, dtype="float32")
    p = 4
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    params = init_pipeline_params(jax.random.PRNGKey(0), cfg, p)
    B, s, m = 8, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, s+1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def ref_loss(params, batch):
        x = embed(params["embed"], batch["tokens"], cfg)
        b_, s_ = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s_, dtype=jnp.int32)[None], (b_, s_))
        kinds = cfg.layer_kinds()
        per = cfg.num_layers // p
        for i in range(p):
            for j in range(per):
                lp = jax.tree.map(lambda a: a[i], params["stages"][j])
                x, _ = apply_layer(lp, x, cfg, kinds[j], pos)
        x = apply_norm(params["final_norm"], x)
        logits = unembed(params["embed"], x, cfg)
        lbl = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(lbl,0)[..., None], -1)[..., 0]
        return jnp.mean(nll)

    with compat.set_mesh(mesh):
        for bpipe in (False, True):
            lossf = make_spmd_train_loss(cfg, mesh, p, num_micro=m, bpipe_stash=bpipe)
            loss = jax.jit(lossf)(params, batch)
            rl_ = ref_loss(params, batch)
            assert abs(float(loss - rl_)) < 1e-5, (bpipe, float(loss), float(rl_))
            g = jax.jit(jax.grad(lossf))(params, batch)
            gr = jax.grad(ref_loss)(params, batch)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)))
            assert err < 1e-5, (bpipe, err)
            txt = jax.jit(jax.grad(lossf)).lower(params, batch).compile().as_text()
            n_cp = txt.count("collective-permute")
            if bpipe:
                assert n_cp > n_plain
            else:
                n_plain = n_cp
    print("SPMD_OK")
""") % SRC


@pytest.mark.slow
def test_spmd_pipeline_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert "SPMD_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# BPipe eviction permutation: a device-level involution
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", [2, 4, 5, 8])
def test_bpipe_perms_round_trip(p):
    """The EVICT permutation must be its own inverse (the LOAD hop uses
    the same pairs), cover every device exactly once, and connect
    exactly the (x, p-1-x) pairs of the paper's Fig. 2."""
    from repro.core.schedule import bpipe_pairs
    from repro.pipeline.spmd import _bpipe_perms
    perm_out, perm_back = _bpipe_perms(p)
    assert perm_out == perm_back                 # involution: EVICT == LOAD
    fwd = dict(perm_out)
    assert len(fwd) == len(perm_out) == p        # total, no duplicates
    assert sorted(fwd) == sorted(fwd.values()) == list(range(p))
    for src, dst in fwd.items():
        assert fwd[dst] == src                   # applying twice = identity
    want = set()
    for a, b in bpipe_pairs(p):
        want |= {(a, b), (b, a)}
    if p % 2:
        want.add((p // 2, p // 2))               # odd middle stage self-maps
    assert set(perm_out) == want


# ---------------------------------------------------------------------------
# hop_distance on a non-contiguous stage -> device layout
# ---------------------------------------------------------------------------
def test_hop_distance_noncontiguous_layout():
    """Regression: the ring wraparound must be measured on the *device
    ring extent*, not p — a sparse layout over a larger mesh axis used
    to under- (or negatively) count the wrap arm."""
    from repro.core import bpipe as BP
    # 4 stages scattered over an 8-device ring: pair (0,3) sits on
    # devices (0, 7) — adjacent across the wraparound, NOT 7 hops
    plan = BP.plan(4, 8, stage_to_device=(0, 5, 2, 7))
    assert BP.ring_extent(plan) == 8
    d = BP.hop_distance(plan)
    assert d[(0, 3)] == 1                        # wrap: min(7, 8-7)
    assert d[(1, 2)] == 3                        # |5-2| vs 8-3
    # an explicit larger physical ring stretches the wrap arm
    d16 = BP.hop_distance(plan, ring_size=16)
    assert d16[(0, 3)] == 7 and d16[(1, 2)] == 3
    # and every distance is a true ring metric: 0 <= d <= extent // 2
    for (a, b), hops in d.items():
        assert 0 <= hops <= 4, (a, b, hops)


# ---------------------------------------------------------------------------
# _remote_remat: gradient parity vs the non-remat stage fn
# ---------------------------------------------------------------------------
REMAT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.pipeline.spmd import _bpipe_perms, _remote_remat

    p = 4
    mesh = compat.make_mesh((p,), ("model",))
    perm_out, perm_back = _bpipe_perms(p)

    def stage_fn(params, x):
        return jnp.tanh(x @ params["w"]) + params["b"]

    remat_fn = _remote_remat(stage_fn, perm_out, perm_back, "model")

    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (8, 8), jnp.float32) * 0.3,
              "b": jnp.float32(0.1)}
    x = jax.random.normal(jax.random.fold_in(k, 1), (p, 2, 8), jnp.float32)

    def loss(fn):
        def inner(params, x):
            y = fn(params, x)
            return jax.lax.psum(jnp.sum(y * y), "model")
        def outer(params, x):
            f = compat.shard_map(inner, mesh=mesh,
                                 in_specs=(P(), P("model")), out_specs=P())
            return f(params, x)
        return outer

    g_plain = jax.jit(jax.grad(loss(stage_fn)))(params, x)
    g_remat = jax.jit(jax.grad(loss(remat_fn)))(params, x)
    for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_remat)):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 1e-6, err

    # the remote stash is real: the remat grad lowers extra
    # collective-permutes (the EVICT out and the LOAD back)
    txt_p = jax.jit(jax.grad(loss(stage_fn))).lower(params, x) \\
        .compile().as_text()
    txt_r = jax.jit(jax.grad(loss(remat_fn))).lower(params, x) \\
        .compile().as_text()
    assert txt_r.count("collective-permute") > txt_p.count(
        "collective-permute"), (txt_r.count("collective-permute"),
                                txt_p.count("collective-permute"))
    print("REMAT_OK")
""") % SRC


@pytest.mark.slow
def test_remote_remat_grad_parity_subprocess():
    r = subprocess.run([sys.executable, "-c", REMAT_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert "REMAT_OK" in r.stdout, r.stdout + r.stderr
