"""SPMD collective pipeline — runs in a subprocess with 8 fake devices
(the main test process must keep the single real CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, %r)
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.configs import get_config
    from repro.pipeline.spmd import init_pipeline_params, make_spmd_train_loss
    from repro.models.blocks import apply_layer
    from repro.models.layers import apply_norm, embed, unembed

    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=4, dtype="float32")
    p = 4
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    params = init_pipeline_params(jax.random.PRNGKey(0), cfg, p)
    B, s, m = 8, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, s+1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def ref_loss(params, batch):
        x = embed(params["embed"], batch["tokens"], cfg)
        b_, s_ = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s_, dtype=jnp.int32)[None], (b_, s_))
        kinds = cfg.layer_kinds()
        per = cfg.num_layers // p
        for i in range(p):
            for j in range(per):
                lp = jax.tree.map(lambda a: a[i], params["stages"][j])
                x, _ = apply_layer(lp, x, cfg, kinds[j], pos)
        x = apply_norm(params["final_norm"], x)
        logits = unembed(params["embed"], x, cfg)
        lbl = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, jnp.maximum(lbl,0)[..., None], -1)[..., 0]
        return jnp.mean(nll)

    with compat.set_mesh(mesh):
        for bpipe in (False, True):
            lossf = make_spmd_train_loss(cfg, mesh, p, num_micro=m, bpipe_stash=bpipe)
            loss = jax.jit(lossf)(params, batch)
            rl_ = ref_loss(params, batch)
            assert abs(float(loss - rl_)) < 1e-5, (bpipe, float(loss), float(rl_))
            g = jax.jit(jax.grad(lossf))(params, batch)
            gr = jax.grad(ref_loss)(params, batch)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)))
            assert err < 1e-5, (bpipe, err)
            txt = jax.jit(jax.grad(lossf)).lower(params, batch).compile().as_text()
            n_cp = txt.count("collective-permute")
            if bpipe:
                assert n_cp > n_plain
            else:
                n_plain = n_cp
    print("SPMD_OK")
""") % SRC


@pytest.mark.slow
def test_spmd_pipeline_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert "SPMD_OK" in r.stdout, r.stdout + r.stderr
