"""Branch-and-bound planner search: the properties the pruning rests on.

Three pillars, each pinned here because ``planner.rank``'s fast path is
only correct while they hold:

  * m-saturation — for every searched kind, a schedule's per-stage peak
    accounting (and its compile-failure behavior, and its move counts'
    monotonicity) is determined by the saturation template at
    ``m = PEAK_SATURATION_FACTOR * p * seq_chunks``; ``feasibility`` and
    the move-time floor price large-m candidates off the small template.
  * dispatch equivalence — ``plan.run(dep_gated=True)`` (the heap/ready-
    queue engine the simulator and executor use) retires the exact
    instruction sequence of the scan loop, greedy and round-robin alike.
  * recommendation identity — the pruned search returns the identical
    recommended plan (per arm and overall, quote lines included) as
    ``exhaustive=True`` on small spaces here and on every registered
    config in the slow-marked sweep.
"""
import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import plan as P
from repro.core import schedule as S
from repro.core.notation import Notation
from repro.planner import (AnalyticCostModel, SearchSpace, plan_config,
                           recommend, report)
from repro.planner import rank as R
from repro.planner import space as SP

SEARCHED_KINDS = ("1f1b", "bpipe", "1f1b_interleaved", "bpipe_interleaved")


# ---------------------------------------------------------------------------
# Pillar 1: peak accounting saturates in m
# ---------------------------------------------------------------------------
def _peak_fields(sch, p):
    return tuple((sch.peak_stash.get(i, 0), sch.peak_spilled.get(i, 0),
                  sch.num_loads.get(i, 0) > 0, sch.bounds.get(i))
                 for i in range(p))


def _saturation_cases():
    for kind in SEARCHED_KINDS:
        entry = S.SCHEDULES[kind]
        assert entry.peak_saturates, kind
        for p in (2, 4, 6):
            vs = (2, 4) if entry.interleaved else (1,)
            for v in vs:
                if entry.interleaved and p * v > 24:
                    continue
                yield kind, p, v


@pytest.mark.parametrize("kind,p,v", list(_saturation_cases()))
def test_peak_accounting_saturates_in_m(kind, p, v):
    """All per-stage quantities feasibility reads are identical for every
    m >= 4*p (the template plan.peak_template_spec binds), and move
    counts are monotone nondecreasing in m past saturation (so the
    move-time floor never over-counts)."""
    msat = P.PEAK_SATURATION_FACTOR * p
    ladder = [msat, 2 * msat, 4 * msat]
    if S.SCHEDULES[kind].interleaved:
        ladder = [m - m % p for m in ladder]
    schs = []
    for m in ladder:
        spec = P.ScheduleSpec(kind, p, m, v=v)
        tpl = P.peak_template_spec(spec)
        assert tpl.m <= msat
        schs.append(P.compile_plan(spec))
        assert _peak_fields(P.compile_plan(tpl), p) \
            == _peak_fields(schs[-1], p), (kind, p, v, m)
    for a, b in zip(schs, schs[1:]):
        for i in range(p):
            assert a.num_evictions.get(i, 0) <= b.num_evictions.get(i, 0)
            assert a.num_loads.get(i, 0) <= b.num_loads.get(i, 0)


def test_unsaturating_kind_is_not_templated():
    """gpipe's peak grows with m (every stash is held to the flush) — it
    must keep peak_saturates=False so peak_template_spec is the
    identity for it."""
    assert not S.SCHEDULES["gpipe"].peak_saturates
    spec = P.ScheduleSpec("gpipe", 4, 64)
    assert P.peak_template_spec(spec) is spec
    small = P.compile_plan(P.ScheduleSpec("gpipe", 4, 16))
    big = P.compile_plan(spec)
    assert big.peak_stash[0] == 64 != small.peak_stash[0]


def test_template_compile_exceptions_match_full_compile():
    """A cap the balancer cannot hold fails identically at template and
    full m — feasibility's except-clause behavior is m-independent."""
    for p, v in ((4, 1), (6, 1)):
        for cap in (2, 3):
            for m in (P.PEAK_SATURATION_FACTOR * p * 2,
                      P.PEAK_SATURATION_FACTOR * p * 4):
                spec = P.ScheduleSpec("bpipe", p, m, cap=cap)
                outcomes = []
                for s in (P.peak_template_spec(spec), spec):
                    try:
                        P.compile_plan(s)
                        outcomes.append(None)
                    except (AssertionError, IndexError, ValueError) as e:
                        outcomes.append(type(e))
                assert outcomes[0] == outcomes[1], (p, cap, m, outcomes)


# ---------------------------------------------------------------------------
# Pillar 2: the event-driven engine retires the scan loop's sequence
# ---------------------------------------------------------------------------
def _dispatch_order(streams, *, greedy, dep_gated):
    """Run with dep-faithful handlers (the scan loop's dependency gate is
    the handler returning BLOCKED; the event engine gates before calling)
    and record the dispatch order the observer sees."""
    order = []
    retired = set()

    class Obs:
        def dispatch(self, i, ins):
            order.append((i, ins.op, ins.mb, ins.chunk, ins.sl, ins.phase))

    def handle(i, ins):
        if ins.dep is not None and ins.dep not in retired:
            return P.BLOCKED
        retired.add(ins.done_key)
        return None

    handlers = {op: handle
                for op in {ins.op for s in streams.values() for ins in s}}
    done = P.run(streams, handlers, greedy=greedy, observer=Obs(),
                 dep_gated=dep_gated)
    return done, order


def _golden_specs():
    path = os.path.join(os.path.dirname(__file__), "golden",
                        "plan_golden.json")
    for c in json.load(open(path)):
        yield P.ScheduleSpec(c["kind"], c["p"], c["m"], v=max(c["v"], 1),
                             cap=c["cap"],
                             residency=c.get("residency", "none"),
                             seq_chunks=c.get("seq_chunks", 1))


def test_event_engine_matches_scan_loop_on_goldens():
    for spec in _golden_specs():
        streams = P.compile_plan(spec).streams
        for greedy in (True, False):
            scan = _dispatch_order(streams, greedy=greedy, dep_gated=False)
            ev = _dispatch_order(streams, greedy=greedy, dep_gated=True)
            assert scan == ev, (spec.label(), greedy)


@given(st.sampled_from(SEARCHED_KINDS), st.integers(2, 6),
       st.integers(1, 4), st.sampled_from([True, False]),
       st.sampled_from([True, False]))
@settings(max_examples=40, deadline=None)
def test_event_engine_matches_scan_loop_fuzzed(kind, p, mf, greedy, deep):
    entry = S.SCHEDULES[kind]
    v = 2 if entry.interleaved else 1
    m = mf * p if entry.interleaved else mf + p
    spec = P.ScheduleSpec(kind, p, m, v=v, depth=2 if deep else 1)
    streams = P.compile_plan(spec).streams
    scan = _dispatch_order(streams, greedy=greedy, dep_gated=False)
    ev = _dispatch_order(streams, greedy=greedy, dep_gated=True)
    assert scan == ev


def test_event_engine_raises_same_deadlock():
    """A stream set with an unsatisfiable dependency deadlocks in both
    engines, with the diagnostic snapshot of per-stream positions."""
    spec = P.ScheduleSpec("1f1b", 2, 4)
    streams = {i: list(s)
               for i, s in P.compile_plan(spec).streams.items()}
    # cut the cross-stream edge supply: drop stream 0 entirely, so
    # stream 1's first F (dep on stage 0's F) can never retire
    streams.pop(0)
    retired = set()

    def handle(i, ins):
        if ins.dep is not None and ins.dep not in retired:
            return P.BLOCKED
        retired.add(ins.done_key)
        return None

    handlers = {op: handle for op in (S.F, S.B)}
    for dep_gated in (False, True):
        with pytest.raises(P.ScheduleDeadlock):
            P.run(streams, handlers, dep_gated=dep_gated)


# ---------------------------------------------------------------------------
# Pillar 3: pruned search == exhaustive search, recommendation-identical
# ---------------------------------------------------------------------------
def _assert_same_recommendation(n, ranked_fast, ranked_full, tag=""):
    assert len(ranked_fast) == len(ranked_full)
    for arm in R.arms_of(ranked_full) + [None]:
        bf, bx = recommend(ranked_fast, arm), recommend(ranked_full, arm)
        cf = bf.cand if bf else None
        cx = bx.cand if bx else None
        assert cf == cx, (tag, arm, cf, cx)
        if bf is not None:
            assert bf.mfu == bx.mfu and bf.makespan == bx.makespan
    lines_f = report.summarize(tag or "cfg", n, ranked_fast)
    lines_x = report.summarize(tag or "cfg", n, ranked_full)
    assert lines_f == lines_x


@given(st.integers(2, 4), st.sampled_from([8, 16]),
       st.sampled_from([1.1, 1.5, 3.0]))
@settings(max_examples=10, deadline=None)
def test_pruned_matches_exhaustive_small(p, B, headroom):
    from repro.core import memory_model as MM
    n = Notation(a=4, b=1, h=256, l=16, s=128, v=512, B=B, p=p, t=1)
    cost = AnalyticCostModel()
    hbm = headroom * MM.max_stage_bytes(n, "recompute", "1f1b")
    cands = list(SP.enumerate_candidates(n, SearchSpace(vs=(2,))))
    fast = R.rank(n, cands, cost, hbm, workspace=0.0)
    full = R.rank(n, cands, cost, hbm, workspace=0.0, exhaustive=True)
    _assert_same_recommendation(n, fast, full, f"p{p}B{B}")
    # the pruned table's non-pruned rows carry the exhaustive numbers
    full_by_cand = {rp.cand: rp for rp in full}
    pruned = 0
    for rp in fast:
        if rp.verdict == "pruned":
            pruned += 1
            continue
        twin = full_by_cand[rp.cand]
        assert (rp.verdict, rp.mfu, rp.makespan, rp.move_time) \
            == (twin.verdict, twin.mfu, twin.makespan, twin.move_time)
        assert rp.note == twin.note
    # every verdict the exhaustive table rejects survives or is pruned —
    # never silently promoted
    for rp in fast:
        if rp.verdict == "ok":
            assert full_by_cand[rp.cand].verdict == "ok"


@pytest.mark.slow
def test_pruned_matches_exhaustive_every_config():
    """The acceptance differential: identical recommended plan (spec,
    cap, depth, residency, b) and summary lines as --exhaustive on all
    registered configs at the paper shape."""
    from benchmarks.planner_sweep import _pow2_at_most
    from repro.configs import get_config, list_configs
    from repro.core.notation import A100_HBM_BYTES, from_model
    for name in list_configs():
        cfg = get_config(name)
        p = min(8, _pow2_at_most(cfg.num_layers))
        n = from_model(cfg, b=1, s=2048, B=128, p=p, t=4)
        fast = plan_config(n, cfg, A100_HBM_BYTES)
        full = plan_config(n, cfg, A100_HBM_BYTES, exhaustive=True)
        _assert_same_recommendation(n, fast, full, name)


def test_bound_is_admissible_for_simulated_rows():
    """Every simulated candidate's MFU stays at or below the ideal-bound
    it was priced with — the inequality the pruning rule needs."""
    from repro.core import memory_model as MM
    n = Notation(a=4, b=1, h=256, l=16, s=128, v=512, B=16, p=4, t=1)
    cost = AnalyticCostModel()
    hbm = 2.0 * MM.max_stage_bytes(n, "recompute", "1f1b")
    cands = list(SP.enumerate_candidates(n, SearchSpace(vs=(2,))))
    ranked = R.rank(n, cands, cost, hbm, workspace=0.0, exhaustive=True)
    for rp in ranked:
        if rp.makespan > 0:
            bound = R.mfu_upper_bound(n, rp.cand, cost)
            assert rp.mfu <= bound + 1e-12, (rp.cand, rp.mfu, bound)


def test_compile_cache_stats_counts_hits_binds_and_evictions():
    P.compile_plan.cache_clear()
    P.compile_cache_stats(reset=True)
    spec = P.ScheduleSpec("1f1b", 4, 16)
    P.compile_plan(spec)
    P.compile_plan(spec)
    deep = P.ScheduleSpec("bpipe", 4, 16, depth=2)
    P.compile_plan(deep)
    stats = P.compile_cache_stats()
    assert stats["hits"] == 1
    # depth != 1 compiles via the depth-1 base: 2 misses for the deep
    # spec (itself + its base), 1 recorded bind
    assert stats["misses"] == 3 and stats["binds"] == 1
    assert stats["size"] == 3 and stats["maxsize"] >= stats["size"]
    # the deep schedule is the base with the spec swapped — same streams
    assert P.compile_plan(deep).streams \
        is P.compile_plan(P.ScheduleSpec("bpipe", 4, 16)).streams
    assert P.compile_plan(deep).spec.depth == 2
