"""Interleaved schedules as first-class runtime citizens: executor
numerics vs the non-pipelined reference, live cap enforcement, the
no-retrace compilation contract, and simulator bubble shrinkage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import memory_model as MM
from repro.core import schedule as S
from repro.core import simulator as SIM
from repro.core.notation import GPT3_96B
from repro.models import model as M
from repro.pipeline import PipelineExecutor
from repro.pipeline import stage as stage_mod

KEY = jax.random.PRNGKey(11)


def _setup(layers, b=8, s=16):
    cfg = get_config("qwen1.5-0.5b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=layers, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ref_loss, _ = M.loss_fn(params, batch, cfg)
    ref_grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    return cfg, params, batch, ref_loss, ref_grads


@pytest.mark.parametrize("kind", ["1f1b_interleaved", "bpipe_interleaved"])
@pytest.mark.parametrize("p", [2, 4])
def test_interleaved_executor_matches_reference(kind, p):
    cfg, params, batch, ref_loss, ref_grads = _setup(layers=2 * p)
    ex = PipelineExecutor(cfg, p=p, kind=kind, micro_batch=2, v=2)
    res = ex.step(params, batch)
    assert abs(float(res.loss - ref_loss)) < 1e-5
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(ref_grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=1e-4)


def test_bpipe_interleaved_cap_live():
    """m large enough that plain-interleaved stage-0 stash (11 units at
    p=4, v=2) exceeds the cap (9): the executor must actually evict and
    the live store must stay bounded — the acceptance criterion."""
    cfg, params, batch, ref_loss, _ = _setup(layers=8)
    ex = PipelineExecutor(cfg, p=4, kind="bpipe_interleaved",
                          micro_batch=1, v=2)
    res = ex.step(params, batch)  # m=8: enforce_cap asserts inside step
    assert abs(float(res.loss - ref_loss)) < 1e-5
    cap = S.bpipe_interleaved_cap(4, 2)
    assert ex.cap == cap
    assert res.stats.evictions > 0 and res.stats.loads == res.stats.evictions
    assert max(res.stats.peak_local.values()) <= cap
    # and the plain-interleaved run really would have exceeded it
    plain = PipelineExecutor(cfg, p=4, kind="1f1b_interleaved",
                             micro_batch=1, v=2).step(params, batch)
    assert max(plain.stats.peak_local.values()) > cap


def test_one_trace_per_stage_fn_per_step():
    """The microbatch rides through jax.vjp as an argument, so each
    (virtual) stage fn traces exactly once — not once per microbatch —
    and a second step() triggers zero new traces."""
    cfg, params, batch, _, _ = _setup(layers=4)
    counts = {}
    orig = stage_mod.make_stage_fn

    def counting_make(cfg_, p_, stage_, remat="none"):
        fn = orig(cfg_, p_, stage_, remat)
        counts[stage_] = 0

        def wrapped(*a):
            counts[stage_] += 1
            return fn(*a)
        return wrapped

    stage_mod.make_stage_fn = counting_make
    try:
        ex = PipelineExecutor(cfg, p=2, kind="1f1b_interleaved",
                              micro_batch=2, v=2)
    finally:
        stage_mod.make_stage_fn = orig
    ex.step(params, batch)
    after_one = dict(counts)
    assert after_one == {vs: 1 for vs in range(4)}, after_one
    ex.step(params, batch)
    assert counts == after_one, (counts, after_one)


def test_interleaved_bubble_shrinks():
    for p, m in [(4, 16), (8, 32)]:
        base = SIM.simulate(SIM.SimConfig(p=p, m=m, Tf=1, Tb=2, kind="1f1b"))
        prev = base.bubble_fraction
        for v in (2, 4):
            il = SIM.simulate(SIM.SimConfig(p=p, m=m, Tf=1, Tb=2,
                                            kind="1f1b_interleaved", v=v))
            assert il.bubble_fraction < prev, (p, m, v)
            assert il.makespan == pytest.approx(
                SIM.interleaved_ideal_makespan(
                    SIM.SimConfig(p=p, m=m, Tf=1, Tb=2, v=v)), rel=1e-9)
            prev = il.bubble_fraction


def test_bpipe_interleaved_sim_free_with_bandwidth():
    base = SIM.simulate(SIM.SimConfig(p=8, m=32, Tf=1, Tb=2,
                                      kind="1f1b_interleaved", v=2))
    bp = SIM.simulate(SIM.SimConfig(p=8, m=32, Tf=1, Tb=2,
                                    kind="bpipe_interleaved", v=2))
    assert bp.makespan == pytest.approx(base.makespan)
    assert bp.load_stall == 0.0


def test_interleaved_memory_model_cap():
    """v-chunk stash byte accounting: bpipe_interleaved peak bytes respect
    the cap x per-unit bytes and undercut plain interleaved."""
    n = GPT3_96B
    plain = MM.per_stage_memory(n, "recompute", "1f1b_interleaved", v=2)
    bal = MM.per_stage_memory(n, "recompute", "bpipe_interleaved", v=2)
    unit = MM.act_bytes_per_stage(n, "recompute", 2)
    cap = S.bpipe_interleaved_cap(n.p, 2)
    assert max(s.peak_stash for s in bal) <= cap
    assert all(s.act_bytes <= cap * unit for s in bal)
    assert max(s.act_bytes for s in bal) <= max(s.act_bytes for s in plain)
