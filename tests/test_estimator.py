"""Paper §4 estimation method: eq. 1-4 + the published validation numbers."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import estimator as E
from repro.core import flops as F
from repro.core.notation import GPT3_96B, LLAMA_65B, Notation


def test_eq1_flops_gpt3():
    n = GPT3_96B
    f = F.paper_flops(n)
    # closed form sanity: 72*b*s*l*h^2 dominates; correction terms small
    base = 72 * n.b * n.s * n.l * n.h**2
    assert f > base
    assert f / base == pytest.approx(1 + n.s / (6 * n.h) + n.v / (16 * n.l * n.h))


def test_paper_headline_prediction():
    """exp (7)->(8): predicted 1.39x vs observed 1.35x."""
    r = E.predicted_vs_observed(GPT3_96B.replace(b=2), 8, 7)
    assert r["predicted"] == pytest.approx(1.39, abs=0.01)
    assert r["observed"] == pytest.approx(1.347, abs=0.005)
    assert 0 < r["gap_pct"] < 5  # the paper attributes the gap to BPipe overhead


def test_flash_rows_predict_negative_result():
    """exp (9)->(10): estimator bound vs the observed *negative* result.
    eq.4 gives the UPPER BOUND of the b=1->2 speedup; the observed 51.7/52.0
    < 1 shows BPipe overhead ate the entire headroom — the paper's thesis."""
    r = E.predicted_vs_observed(GPT3_96B.replace(b=2), 10, 9)
    assert r["predicted"] > 1.0
    assert r["observed"] < 1.0
    assert r["predicted"] == pytest.approx(1.027, abs=0.01)


def test_llama_bpipe_negative():
    """exp (5)->(6): LLaMA flash b=2 (no BPipe) vs b=4 (BPipe) — estimator
    headroom is tiny, observed is clearly negative."""
    n = LLAMA_65B.replace(b=4)
    r = E.predicted_vs_observed(n, 6, 5)
    assert r["predicted"] == pytest.approx(
        (128 + 2 * 7) / (128 + 4 * 7) * (61.9 / 58.6), abs=1e-6)
    assert r["observed"] < 0.95


@given(st.integers(1, 5), st.integers(2, 16),
       st.floats(0.2, 0.8), st.floats(0.2, 0.8))
@settings(max_examples=50, deadline=None)
def test_eq3_eq4_consistency(log2b, p, mfux, mfuy):
    """MFU(x)/MFU(y) from eq.3 equals eq.4 directly."""
    bx = 2 ** log2b
    by = 1
    B = 128
    nx = Notation(a=8, b=bx, h=1024, l=16, s=2048, v=32000, B=B, p=p, t=4)
    ny = nx.replace(b=by)
    Fm, Fs = 1e15, 1e15 / p
    mx = E.mfu_model(nx, Fm, Fs, mfux)
    my = E.mfu_model(ny, Fm, Fs, mfuy)
    ratio = E.speedup(nx, bx, by, mfux, mfuy)
    assert mx / my == pytest.approx(ratio, rel=1e-9)


@given(st.integers(2, 16), st.integers(0, 4))
@settings(max_examples=50, deadline=None)
def test_mfu_decreases_with_bubble(p, log2b):
    """For fixed stage MFU, larger b costs bubble efficiency (eq. 3)."""
    b = 2 ** log2b
    n = Notation(a=8, b=b, h=1024, l=16, s=2048, v=32000, B=128, p=p, t=4)
    if 128 % b:
        return
    m1 = E.mfu_model(n, 1e15, 1e15 / p, 0.5)
    m2 = E.mfu_model(n.replace(b=2 * b), 1e15, 1e15 / p, 0.5)
    assert m2 < m1


def test_required_stage_gain_explains_llama():
    """The break-even corollary: LLaMA's measured stage gain (61.9/58.6 =
    1.056) is below the b=2->4 bubble penalty (1.099) — BPipe *had* to
    lose, independent of implementation quality."""
    n = LLAMA_65B
    need = E.required_stage_gain(n, 4, 2)
    assert need == pytest.approx((128 + 4 * 7) / (128 + 2 * 7), rel=1e-9)
    measured = 61.9 / 58.6
    assert measured < need
    # GPT-3 recompute b=1->2: measured 55.2/37.8 = 1.46 >> required 1.052
    assert 55.2 / 37.8 > E.required_stage_gain(GPT3_96B, 2, 1)
    # consistency with eq.4: speedup == 1 exactly at the required gain
    sp = E.speedup(n.replace(b=4), 4, 2, need * 0.586, 0.586)
    assert sp == pytest.approx(1.0, rel=1e-9)


def test_llama_ffn_flops_equal_gpt3_form():
    """Paper §3.1: LLaMA's three 8/3h FFN matmuls == GPT-3's 16bsh^2."""
    h, b, s = 8192, 2, 2048
    three_matmul = 3 * 2 * (8.0 / 3.0) * b * s * h * h
    gpt3_ffn = 16 * b * s * h * h
    assert three_matmul == pytest.approx(gpt3_ffn)


def test_arch_flops_positive_all():
    from repro.configs import ASSIGNED, get_config
    for a in ASSIGNED:
        cfg = get_config(a)
        f = F.model_flops_train(cfg, 1, 2048)
        nd = F.model_flops_6nd(cfg, 1, 2048)
        assert f > 0 and nd > 0
        # 6ND and matmul-census agree within ~3x for non-MoE LMs
        if cfg.moe is None and not cfg.is_encdec:
            assert 0.3 < f / nd < 3.0, (a, f / nd)
