"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward + one train step on CPU with
shape and finiteness assertions. Full configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, list_configs, shape_applicable
from repro.configs.base import TrainConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.models import model as M
from repro.train.steps import init_all, make_train_step


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 or cfg.num_layers == len(cfg.block_pattern)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params, opt = init_all(cfg)
    b, s = 2, 32
    dc = DataConfig(batch=b, seq_len=s)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dc, 0).items()}
    n_text = batch["tokens"].shape[1]

    logits, aux = M.forward(params, batch, cfg)
    assert logits.shape == (b, n_text, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tcfg = TrainConfig(global_batch=b, micro_batch=b, seq_len=s,
                       steps=5, warmup_steps=1)
    step = make_train_step(cfg, tcfg, donate=False)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed


def test_registry_complete():
    for a in ASSIGNED:
        assert get_config(a).name == a
    assert "gpt3-96b" in list_configs() and "llama-65b" in list_configs()
    with pytest.raises(KeyError):
        get_config("nope")


def test_exact_assigned_dimensions():
    """The public-pool table, verbatim."""
    spec = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        got_ff = c.moe.d_ff if c.moe else c.d_ff
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                got_ff, c.vocab_size) == (l, d, h, kv, ff, v), arch
    assert get_config("llama4-scout-17b-a16e").moe.num_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe.top_k == 1
    assert get_config("granite-moe-1b-a400m").moe.num_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.top_k == 8


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md skips)."""
    long = INPUT_SHAPES["long_500k"]
    runs = {a for a in ASSIGNED if shape_applicable(get_config(a), long)}
    assert runs == {"recurrentgemma-2b", "xlstm-125m"}
    assert shape_applicable(get_config("qwen1.5-0.5b-swa"), long)
    for a in ASSIGNED:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), INPUT_SHAPES[s])


def test_param_counts_plausible():
    approx = {
        "qwen3-14b": 14e9, "gemma2-9b": 9e9, "qwen1.5-32b": 32e9,
        "qwen1.5-0.5b": 0.5e9, "xlstm-125m": 0.125e9,
        "llama4-scout-17b-a16e": 100e9,  # total (not active) params
        "granite-moe-1b-a400m": 1.3e9,
    }
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.45 * n < got < 2.2 * n, (arch, got, n)
