"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _qkv(b, sq, sk, nq, nkv, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, nq, hd), dtype)
    k = jax.random.normal(ks[1], (b, sk, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, sk, nkv, hd), dtype)
    return q, k, v


SWEEP = [
    # b, s, nq, nkv, hd, dtype, window, softcap
    (2, 64, 4, 2, 32, "float32", 0, 0.0),
    (2, 64, 4, 1, 32, "float32", 16, 0.0),
    (1, 96, 8, 8, 16, "float32", 0, 20.0),
    (2, 64, 4, 2, 32, "bfloat16", 0, 0.0),
    (1, 40, 2, 2, 64, "float32", 0, 0.0),    # non-divisible -> padding
    (1, 128, 16, 4, 8, "float32", 32, 50.0),  # window + softcap
    (3, 32, 2, 2, 128, "bfloat16", 8, 0.0),
]


@pytest.mark.parametrize("b,s,nq,nkv,hd,dtype,window,softcap", SWEEP)
def test_flash_attention_sweep(b, s, nq, nkv, hd, dtype, window, softcap):
    q, k, v = _qkv(b, s, s, nq, nkv, hd, dtype)
    got = ops.flash_attention(q, k, v, True, window, softcap, None,
                              32, 32, True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   softcap=softcap)
    tol = 2.5e-2 if dtype == "bfloat16" else 3e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol)


def test_flash_attention_jit():
    q, k, v = _qkv(1, 64, 64, 4, 4, 32, "float32")
    f = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, True, 0, 0.0, None, 32, 32, True))
    np.testing.assert_allclose(
        np.asarray(f(q, k, v)),
        np.asarray(ref.flash_attention_ref(q, k, v)), atol=3e-5)


@pytest.mark.parametrize("s,nq,nkv,hd,window,softcap", [
    (32, 4, 2, 16, 0, 0.0),
    (64, 4, 1, 32, 16, 0.0),     # GQA + sliding window
    (48, 8, 8, 16, 0, 20.0),     # softcap chain rule
    (40, 2, 2, 32, 0, 0.0),      # non-divisible -> padding path
])
def test_flash_attention_bwd_kernels(s, nq, nkv, hd, window, softcap):
    """Pallas two-pass backward (dq + dk/dv kernels) vs oracle vjp."""
    q, k, v = _qkv(1, s, s, nq, nkv, hd, "float32")
    g = jax.random.normal(jax.random.fold_in(KEY, 9), q.shape)

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, window, softcap,
                                           None, 16, 16, True) * g)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(
            q, k, v, causal=True, window=window, softcap=softcap) * g)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=1e-3)


def test_flash_attention_lse():
    q, k, v = _qkv(1, 32, 32, 4, 2, 16, "float32")
    from repro.kernels.flash_attention import flash_attention_fwd
    out, lse = flash_attention_fwd(q, k, v, interpret=True, block_q=16,
                                   block_k=16, return_lse=True)
    # oracle lse
    s = jnp.einsum("bqgmh,bkgh->bqgmk",
                   q.reshape(1, 32, 2, 2, 16), k) / np.sqrt(16)
    mask = jnp.tril(jnp.ones((32, 32), bool))
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    want = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("shape,dtype,scale,causal", [
    ((4, 64, 64), "float32", 1.0, False),
    ((2, 4, 32, 32), "bfloat16", 0.125, True),
    ((1, 8, 48, 48), "float32", 0.07, True),
    ((96, 128), "float32", 2.0, False),
])
def test_fused_softmax_sweep(shape, dtype, scale, causal):
    x = jax.random.normal(KEY, shape, dtype) * 4
    got = ops.fused_softmax(x, scale, causal, 16, True)
    want = ref.fused_softmax_ref(x, scale=scale, causal=causal)
    tol = 2e-2 if dtype == "bfloat16" else 1e-6
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol)
    # rows sum to 1
    s = np.asarray(got, np.float32).sum(-1)
    np.testing.assert_allclose(s, np.ones_like(s), atol=2e-2)


def test_fused_softmax_grad_kernel():
    x = jax.random.normal(KEY, (2, 2, 16, 16), jnp.float32)
    g1 = jax.grad(lambda x: jnp.sum(
        ops.fused_softmax(x, 0.5, True, 8, True) ** 2))(x)
    g2 = jax.grad(lambda x: jnp.sum(
        ref.fused_softmax_ref(x, scale=0.5, causal=True) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-5, rtol=1e-4)


def test_unfused_chain_matches_fused():
    """The paper's exp-(7) unfused chain is numerically identical — only
    the kernel count differs (that's the whole point of §3.2)."""
    x = jax.random.normal(KEY, (4, 32, 32), jnp.bfloat16)
    a = ops.unfused_softmax_chain(x, scale=0.3, causal=True)
    b = ops.fused_softmax(x, 0.3, True, 16, True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-2)


def test_train_step_with_flash_impl():
    """End-to-end: a full train step with attn_impl='flash' (Pallas fwd +
    Pallas bwd kernels inside the model) matches the reference impl."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import model as M
    cfg_ref = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                                  dtype="float32")
    cfg_fa = dataclasses.replace(cfg_ref, attn_impl="flash")
    params = M.init_params(KEY, cfg_ref)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg_ref.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    l1, g1 = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg_ref)[0])(params)
    l2, g2 = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg_fa)[0])(params)
    assert abs(float(l1 - l2)) < 1e-4
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


def test_flash_in_model_attention():
    """attention(impl='flash') == attention(impl='reference') in-model."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import attention as A
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              dtype="float32")
    p = A.init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    o1, _ = A.attention(p, x, cfg, pos, kind="attn", impl="reference")
    o2, _ = A.attention(p, x, cfg, pos, kind="attn", impl="flash")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-4, rtol=1e-3)
