"""Data pipeline, optimizer, checkpointing, sharding rules."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.configs.base import TrainConfig
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, make_batch
from repro.optim import adam
from repro.sharding import rules


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_data_deterministic_and_shaped():
    cfg = get_config("internvl2-1b").reduced()
    dc = DataConfig(batch=4, seq_len=32, seed=1)
    b1, b2 = make_batch(cfg, dc, 5), make_batch(cfg, dc, 5)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    b3 = make_batch(cfg, dc, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    n_text = 32 - cfg.num_prefix_embeds
    assert b1["tokens"].shape == (4, n_text)
    assert b1["prefix_embeds"].shape == (4, cfg.num_prefix_embeds, cfg.d_model)
    assert b1["tokens"].max() < cfg.vocab_size and b1["tokens"].min() >= 0


def test_data_encdec():
    cfg = get_config("whisper-small").reduced()
    b = make_batch(cfg, DataConfig(batch=2, seq_len=16), 0)
    assert "enc_embeds" in b and b["enc_embeds"].shape[0] == 2


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adam_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, steps=100,
                       weight_decay=0.0, grad_clip=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adam.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state, _ = adam.update(params, grads, state, tcfg)
    # cosine decay floors the lr at 10%, so convergence is approximate
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.5


def test_grad_clip():
    tcfg = TrainConfig(learning_rate=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"x": jnp.zeros(3)}
    state = adam.init(params)
    big = {"x": jnp.array([1e6, 1e6, 1e6])}
    _, _, m = adam.update(params, big, state, tcfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, steps=100)
    lrs = [float(adam.lr_schedule(tcfg, s)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[-1] < lrs[20]


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_ckpt_roundtrip_nested():
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16)},
            "c": [jnp.ones(4), jnp.zeros((2, 2), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        ckpt.save(p, tree)
        back = ckpt.restore(p, tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_shape_mismatch_raises():
    tree = {"a": jnp.ones(3)}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npz")
        ckpt.save(p, tree)
        with pytest.raises(ValueError):
            ckpt.restore(p, {"a": jnp.ones(4)})


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = _FakeMesh(data=16, model=16)
MESH3 = _FakeMesh(pod=2, data=16, model=16)


@given(st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 24, 40, 128, 256_000]),
                min_size=1, max_size=4))
@settings(max_examples=60, deadline=None)
def test_legalize_always_divides(dims):
    spec = P(*( ["model"] + [None] * (len(dims) - 1)))
    out = rules.legalize(spec, tuple(dims), MESH)
    for d, entry in enumerate(out):
        if entry is not None:
            assert dims[d] % rules._axis_size(MESH, entry) == 0


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh", [MESH, MESH3])
def test_param_specs_legal_all_archs(arch, mesh):
    from repro.launch import specs as sp
    cfg = get_config(arch)
    pspec = sp.param_specs(cfg)
    specs = rules.param_specs(pspec, mesh)
    flat = jax.tree_util.tree_flatten_with_path(pspec)[0]
    spec_flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(spec_flat)
    n_model_sharded = 0
    for (path, leaf), spec in zip(flat, spec_flat):
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            assert leaf.shape[d] % rules._axis_size(mesh, entry) == 0, (
                arch, path, leaf.shape, spec)
            n_model_sharded += 1
    assert n_model_sharded > 0, arch  # something actually shards


def test_moe_experts_shard_over_model():
    from repro.launch import specs as sp
    cfg = get_config("granite-moe-1b-a400m")
    pspec = sp.param_specs(cfg)
    specs = rules.param_specs(pspec, MESH)
    s = specs["blocks"]["pos0"]["ffn"]["wi"]
    assert s[1] == "model"  # (stack, E, d, f): experts dim sharded


def test_cache_auto_policy():
    """§Perf-measured policy: split-KV (seq-sharded cache) for GQA archs
    (gemma2); head-sharding for MHA (qwen1.5-32b)."""
    from repro.configs import INPUT_SHAPES
    from repro.launch import specs as sp

    def kv_spec(arch):
        cfg = get_config(arch)
        st_ = sp.decode_state_specs(cfg, INPUT_SHAPES["decode_32k"])
        specs = rules.cache_specs(st_, MESH, strategy="auto", cfg=cfg)
        key = "pos0" if "pos0" in specs else "rem0"
        layer = specs[key]
        while "k" not in layer:  # nested pattern positions
            layer = next(iter(layer.values()))
        return layer["k"]

    gem = kv_spec("gemma2-9b")      # GQA (kv=8 < 16 heads) -> seq sharded
    assert gem[2] == "model"
    assert len(gem) <= 3 or gem[3] is None  # kv-head dim unsharded
    qw = kv_spec("qwen1.5-32b")     # MHA -> head/hd sharding retained
    assert len(qw) <= 2 or qw[2] != "model"
    # recurrent-state archs unaffected by the policy
    xl = rules.cache_specs(
        sp.decode_state_specs(get_config("xlstm-125m"),
                              INPUT_SHAPES["decode_32k"]),
        MESH, strategy="auto", cfg=get_config("xlstm-125m"))
    assert xl


def test_cache_specs_legal():
    from repro.configs import INPUT_SHAPES
    from repro.launch import specs as sp
    for arch in ("qwen1.5-32b", "recurrentgemma-2b", "xlstm-125m"):
        cfg = get_config(arch)
        st_ = sp.decode_state_specs(cfg, INPUT_SHAPES["decode_32k"])
        specs = rules.cache_specs(st_, MESH)
        flat = jax.tree_util.tree_flatten_with_path(st_)[0]
        spec_flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat, spec_flat):
            for d, entry in enumerate(spec):
                if entry is not None:
                    assert leaf.shape[d] % rules._axis_size(MESH, entry) == 0, (
                        arch, path, leaf.shape, spec)
