"""Memory model: the paper's implicit memory story, reproduced."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import memory_model as MM
from repro.core.notation import A100_HBM_BYTES, GPT3_96B, LLAMA_65B


def test_gpt3_b2_needs_bpipe():
    """Why exp (8) required BPipe: b=2 recompute OOMs under 1F1B on
    A100-80G but fits with BPipe — and b=1 fits without."""
    n = GPT3_96B
    assert MM.fits(n.replace(b=1), "recompute", "1f1b", A100_HBM_BYTES)
    assert not MM.fits(n.replace(b=2), "recompute", "1f1b", A100_HBM_BYTES)
    assert MM.fits(n.replace(b=2), "recompute", "bpipe", A100_HBM_BYTES)


def test_llama_b4_needs_bpipe_with_flash():
    """Paper exp (5)/(6): b=2 flash fits plain 1F1B; b=4 needs BPipe."""
    n = LLAMA_65B
    assert MM.fits(n.replace(b=2), "flash", "1f1b", A100_HBM_BYTES)
    assert not MM.fits(n.replace(b=4), "flash", "1f1b", A100_HBM_BYTES)
    assert MM.fits(n.replace(b=4), "flash", "bpipe", A100_HBM_BYTES)


def test_max_micro_batch():
    assert MM.max_micro_batch(GPT3_96B, "recompute", "1f1b", A100_HBM_BYTES) == 1
    assert MM.max_micro_batch(GPT3_96B, "recompute", "bpipe", A100_HBM_BYTES) == 2
    assert MM.max_micro_batch(LLAMA_65B, "flash", "bpipe", A100_HBM_BYTES) >= 4


def test_attention_none_dominates():
    """Unrecomputed attention stores the 5as^2b/t quadratic term."""
    n = GPT3_96B
    none = MM.act_bytes_per_layer(n, "none")
    rec = MM.act_bytes_per_layer(n, "recompute")
    fl = MM.act_bytes_per_layer(n, "flash")
    assert none > rec == fl
    assert none - rec == pytest.approx(5 * n.a * n.s**2 * n.b / n.t)


@given(st.integers(2, 16), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_bpipe_balances_activation_memory(p, bm):
    n = GPT3_96B.replace(p=p, b=bm if 128 % bm == 0 else 1)
    rep = MM.balance_report(n, "recompute")
    spread_1f1b = max(rep["1f1b"]) - min(rep["1f1b"])
    spread_bpipe = max(rep["bpipe"]) - min(rep["bpipe"])
    assert spread_bpipe <= spread_1f1b
    assert max(rep["bpipe"]) <= max(rep["1f1b"])


@given(st.integers(0, 3))
@settings(max_examples=10, deadline=None)
def test_memory_monotone_in_microbatch(log2b):
    # keep m = B/(2b) >= p so the peak stash count stays saturated and
    # the comparison isolates the per-microbatch byte growth
    b = 2 ** log2b
    lo = MM.max_stage_bytes(GPT3_96B.replace(b=b), "flash", "1f1b")
    hi = MM.max_stage_bytes(GPT3_96B.replace(b=2 * b), "flash", "1f1b")
    assert hi > lo


def test_eviction_bytes_positive():
    assert MM.eviction_bytes(GPT3_96B, "recompute") > 0
