"""Property tests (hypothesis) on the pipeline schedules — the system's
core invariants."""
from hypothesis import given, settings, strategies as st

from repro.core import schedule as S

pm = st.tuples(st.integers(2, 24), st.integers(1, 6)).map(
    lambda t: (t[0], t[0] * t[1]))  # m >= p keeps the steady state exercised


@given(pm)
@settings(max_examples=60, deadline=None)
def test_1f1b_peak_is_p_minus_x(t):
    p, m = t
    peaks = S.peak_stash("1f1b", p, m)
    for i in range(p):
        assert peaks[i] == min(p - i, m)


@given(pm)
@settings(max_examples=60, deadline=None)
def test_bpipe_cap_respected(t):
    p, m = t
    peaks = S.peak_stash("bpipe", p, m)
    cap = S.bpipe_cap(p)
    assert max(peaks.values()) <= cap
    # and BPipe actually balances: spread is <= half the 1F1B spread
    p1 = S.peak_stash("1f1b", p, m)
    if p >= 4:
        assert (max(peaks.values()) - min(peaks.values())
                <= max(p1.values()) - min(p1.values()))


@given(pm, st.sampled_from(["gpipe", "1f1b", "bpipe"]))
@settings(max_examples=60, deadline=None)
def test_streams_well_formed(t, kind):
    p, m = t
    streams = S.build(kind, p, m)
    for i in range(p):
        st_ = streams[i]
        fs = [x.mb for x in st_ if x.op == S.F]
        bs = [x.mb for x in st_ if x.op == S.B]
        assert fs == list(range(m)) and bs == list(range(m))
        held = set()
        for x in st_:
            if x.op == S.F:
                assert x.mb not in held
                held.add(x.mb)
            elif x.op == S.EVICT:
                assert x.mb in held
                held.discard(x.mb)
            elif x.op == S.LOAD:
                assert x.mb not in held
                held.add(x.mb)
            else:
                assert x.mb in held, (kind, p, m, i, x)
                held.discard(x.mb)
        assert not held


@given(pm)
@settings(max_examples=40, deadline=None)
def test_non_bpipe_schedules_never_evict(t):
    p, m = t
    for kind in ("gpipe", "1f1b"):
        for i in range(p):
            assert all(x.op in (S.F, S.B) for x in S.build(kind, p, m)[i])


@given(pm)
@settings(max_examples=40, deadline=None)
def test_eviction_counts_monotone_in_stage(t):
    """Earlier stages hold more 1F1B stash => need >= as many evictions."""
    p, m = t
    ev = [S.num_evictions(p, m, i) for i in range(p)]
    assert all(a >= b for a, b in zip(ev, ev[1:]))
    # acceptor halves never evict
    for i in range(p // 2 + (p % 2), p):
        assert ev[i] == 0


def test_gpipe_peak_is_m():
    peaks = S.peak_stash("gpipe", 4, 12)
    assert all(v == 12 for v in peaks.values())


def test_cap_formula():
    assert [S.bpipe_cap(p) for p in (2, 3, 4, 8, 16)] == [2, 3, 3, 5, 9]
