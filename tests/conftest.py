import os
import sys

# Tests run on the single real CPU device (the 512-device override is
# exclusive to launch/dryrun.py). Keep compilation single-threaded-ish to
# avoid oversubscribing the 1-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests import hypothesis; this offline container has no wheel for
# it. Fall back to the deterministic stub (same API surface the suite uses)
# so the suite still collects and runs; the real package wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
