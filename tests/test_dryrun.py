"""Dry-run integration: one real combo lowers+compiles on the production
mesh in a subprocess (512 fake devices), plus HLO-parsing unit tests."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
      %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%sum
      %cp = (f32[2,4]{1,0}, f32[2,4]{1,0}) collective-permute-start(%z)
      %junk = f32[2] add(%a, %b)
    """
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 2
    assert got["all-reduce"] == 64 * 4
    assert got["collective-permute"] == 0  # tuple-result start not counted
    assert got["all-to-all"] == 0


def test_extrapolation():
    from repro.launch.roofline import extrapolate
    c1 = {"flops": 10.0, "bytes": 100.0}
    c2 = {"flops": 16.0, "bytes": 130.0}
    out = extrapolate(c1, c2, 10)
    assert out["flops"] == pytest.approx(4 + 6 * 10)
    assert out["bytes"] == pytest.approx(70 + 30 * 10)


def test_roofline_terms():
    from repro.launch.roofline import RooflineTerms
    t = RooflineTerms(flops=197e12, bytes_hbm=819e9, bytes_collective=0.0,
                      chips=256)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0)
    assert t.dominant in ("compute", "memory")
    assert t.mfu(197e12 / 2) == pytest.approx(0.5)


@pytest.mark.slow
def test_dryrun_one_combo_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-125m", "--shape", "decode_32k",
         "--mesh", "single", "--no-roofline", "--force",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=600, env=env, cwd=ROOT)
    assert "OK   xlstm-125m decode_32k single" in r.stdout, (
        r.stdout + r.stderr)
    rec = json.load(open(tmp_path / "xlstm-125m__decode_32k__single.json"))
    assert rec["full"]["t_compile_s"] > 0
    assert rec["full"]["cost_raw"]["flops"] > 0
