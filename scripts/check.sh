#!/usr/bin/env bash
# Offline-safe repo check: byte-compile everything, then run tier-1.
#
#   scripts/check.sh            # full tier-1 (includes slow tests)
#   scripts/check.sh -m 'not slow'   # extra pytest args pass through
#
# Needs no network and no PYTHONPATH fiddling (pyproject sets
# pythonpath=["src"]); hypothesis is optional (tests/conftest.py falls
# back to the deterministic stub in tests/_hypothesis_stub.py).
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples tests
python -m pytest -q "$@"
