#!/usr/bin/env bash
# Offline-safe repo check: byte-compile everything, then run tier-1.
#
#   scripts/check.sh            # full tier-1 (includes slow tests)
#   scripts/check.sh -m 'not slow'   # extra pytest args pass through
#
# Needs no network and no PYTHONPATH fiddling (pyproject sets
# pythonpath=["src"]); hypothesis is optional (tests/conftest.py falls
# back to the deterministic stub in tests/_hypothesis_stub.py).
#
# Env knobs:
#   REPRO_FUZZ_EXAMPLES       differential-harness simulator examples (200)
#   REPRO_FUZZ_EXEC_EXAMPLES  differential-harness executor examples (6)
#   REPRO_TEST_BUDGET_S       per-test duration budget for the grep below
#                             (default 120 here for slow dev boxes; CI
#                             pins 30 so a tier-1 test cannot silently
#                             regress past 30s on a standard runner)
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples tests

# Architecture guard: exactly ONE ready-instruction dispatch loop exists
# (plan.run). A second "while remaining" loop means a module grew its own
# scheduler again — the regression the compiled-plan refactor removed.
loops=$(grep -rl --include='*.py' "while remaining" src/repro)
if [ "$loops" != "src/repro/core/plan.py" ]; then
    echo "ready-loop guard failed: expected only src/repro/core/plan.py," >&2
    echo "found: $loops" >&2
    exit 1
fi

# Observability guard: exactly ONE module constructs trace spans
# (obs/events.py — engines emit through Observer.emit, exporters rebuild
# through the factory helpers). A second "Span(" constructor means a
# side-channel trace schema grew back — the drift the unified event
# stream removed (docs/observability.md).
spans=$(grep -rl --include='*.py' "Span(" src/repro)
if [ "$spans" != "src/repro/obs/events.py" ]; then
    echo "span-emission guard failed: expected only src/repro/obs/events.py," >&2
    echo "found: $spans" >&2
    exit 1
fi

# Differential schedule-fuzz harness, seeded + bounded: random valid
# ScheduleSpecs must keep the executor bit-identical to unmanaged
# execution, the simulator above the ideal bound / engine-order
# invariant, and executor bytes agreeing with the memory model. The
# hypothesis stub draws from a fixed seed, so a red run reproduces; a
# failing spec is written to fuzz_failures.json (CI uploads it).
rm -f fuzz_failures.json
REPRO_FUZZ_EXAMPLES="${REPRO_FUZZ_EXAMPLES:-200}" \
REPRO_FUZZ_EXEC_EXAMPLES="${REPRO_FUZZ_EXEC_EXAMPLES:-6}" \
    python -m pytest -q tests/test_differential.py

# Benchmark suite on tiny CPU-only shapes (includes the planner sweep
# over the two smallest configs and the long-context slicing sweep) —
# schedule/planner regressions fail here, not just in tier-1. The
# tracked copy under benchmarks/ records the smoke trajectory in-repo;
# a diff on it in review IS the perf report.
PYTHONPATH=src python -m benchmarks.run --smoke > /dev/null
cp BENCH_smoke.json benchmarks/BENCH_smoke.json

# Slicing must not perturb the baseline engine: every unsliced golden
# case's makespan is recomputed from a fresh compile and compared
# against the pinned fixture — seq_chunks=1 stays bit-identical.
PYTHONPATH=src python - <<'PYEOF'
import json
import repro.core.plan as P
import repro.core.simulator as SIM
cases = [c for c in json.load(open("tests/golden/plan_golden.json"))
         if "residency" not in c and c.get("seq_chunks", 1) == 1]
assert len(cases) == 30, f"unsliced golden census changed: {len(cases)}"
for c in cases:
    spec = P.ScheduleSpec(c["kind"], c["p"], c["m"],
                          v=max(c["v"], 1), cap=c["cap"])
    res = SIM.simulate(SIM.SimConfig(
        spec=spec, Tf=1.0, Tb=2.0, t_p2p=0.125,
        evict_bytes=1.0, pair_bw=2.0, pair_hops=1))
    assert res.makespan == c["makespan"], (
        f"seq_chunks=1 makespan drifted for {spec.label()}: "
        f"{res.makespan} != {c['makespan']}")
print("golden seq_chunks=1 makespans unchanged (30 cases)")
PYEOF

# Planner acceptance verdicts (paper Table 3): BPipe must win
# GPT-3-recompute and lose LLaMA. (Captured first, then grepped:
# `cli | grep -q` races — grep exits at the first match and SIGPIPEs
# the still-printing CLI, which pipefail turns into a flaky failure.)
# The winning plan's simulated timeline is exported alongside the
# verdict (one event schema end to end — the same CLI answers "which
# plan" and "what does its step look like"); CI uploads the trace on
# failure so a red verdict arrives with its timeline attached.
gpt3_out=$(PYTHONPATH=src python -m repro.launch.plan --config gpt3_96b \
    --attention recompute --top 0 --perfetto plan_trace.perfetto.json \
    --metrics-json plan_metrics.json)
grep -q 'PLAN gpt3-96b \[recompute\]: bpipe' <<< "$gpt3_out"
test -s plan_trace.perfetto.json
test -s plan_metrics.json
llama_out=$(PYTHONPATH=src python -m repro.launch.plan --config llama_65b \
    --top 0)
grep -q 'PLAN llama-65b: 1f1b' <<< "$llama_out"

# Vocab-parallel verdict (docs/memory.md "Vocab accounting"): at 14 GiB
# the 151k-vocab qwen3-14b is infeasible unscattered — opening the vp
# ladder must recover a vp=4 BPipe plan. The Table 3 greps above run
# with the default (unscattered) space, so they double as the
# vocab_parallel=1 no-change guard.
qwen_out=$(PYTHONPATH=src python -m repro.launch.plan --config qwen3_14b \
    --attention recompute --hbm-gb 14 --vocab-parallel 1 2 4 8 --top 0)
grep -q 'PLAN qwen3-14b \[recompute\]: bpipe .*vp=4' <<< "$qwen_out"

# Planner-speed gate: the branch-and-bound search must keep the FULL
# 13-config sweep fast (the perf_opt this repo ships — see
# docs/planner.md "Search performance"). Budget is generous vs the ~7s
# measured so slow CI boxes pass, but a pruning regression that falls
# back to exhaustive-scale work (~42s at HEAD before the B&B search)
# fails loudly. Counters print alongside so a red run says what the
# search did.
speed_budget="${REPRO_PLANNER_SWEEP_BUDGET_S:-25}"
PYTHONPATH=src python - "$speed_budget" <<'PYEOF'
import sys, time
from benchmarks import planner_sweep
budget = float(sys.argv[1])
t0 = time.perf_counter()
planner_sweep.main(print_csv=False, smoke=False)
dt = time.perf_counter() - t0
m = planner_sweep.LAST_METRICS
print(f"planner sweep: {dt:.2f}s over 13 configs — "
      f"{m['enumerated']} enumerated, {m['simulated']} simulated, "
      f"{m['pruned']} pruned (budget {budget:.0f}s)")
assert dt <= budget, (
    f"planner sweep took {dt:.2f}s > {budget:.0f}s budget — "
    f"branch-and-bound pruning regressed?")
PYEOF

# Tier-1 with a per-test wall-clock budget: --durations surfaces the
# slowest tests and the awk grep fails the run if any single test
# exceeds the budget — a silent 10x slowdown in one test is a
# regression even while green. Exemptions: the differential harness
# already ran (seeded + bounded) above, and slow-MARKED tests are
# declared slow, not silently slow — they run un-budgeted afterwards.
# (pytest exit 5 = "no tests collected" — fine in either phase when
# pass-through args select only slow, or only non-slow, tests)
budget="${REPRO_TEST_BUDGET_S:-120}"
durations_log=$(mktemp)
fast_rc=0
python -m pytest -q --durations=10 -m "not slow" \
    --ignore=tests/test_differential.py "$@" \
    | tee "$durations_log" || fast_rc=$?
[ "$fast_rc" -eq 0 ] || [ "$fast_rc" -eq 5 ]
awk -v budget="$budget" '
    /^[0-9.]+s (call|setup|teardown)/ {
        if ($1 + 0 > budget) { print "over budget (" budget "s):", $0; bad = 1 }
    }
    END { exit bad }
' "$durations_log"
rm -f "$durations_log"
slow_rc=0
python -m pytest -q -m "slow" --ignore=tests/test_differential.py "$@" \
    || slow_rc=$?
[ "$slow_rc" -eq 0 ] || [ "$slow_rc" -eq 5 ]
