#!/usr/bin/env bash
# Offline-safe repo check: byte-compile everything, then run tier-1.
#
#   scripts/check.sh            # full tier-1 (includes slow tests)
#   scripts/check.sh -m 'not slow'   # extra pytest args pass through
#
# Needs no network and no PYTHONPATH fiddling (pyproject sets
# pythonpath=["src"]); hypothesis is optional (tests/conftest.py falls
# back to the deterministic stub in tests/_hypothesis_stub.py).
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples tests

# Architecture guard: exactly ONE ready-instruction dispatch loop exists
# (plan.run). A second "while remaining" loop means a module grew its own
# scheduler again — the regression the compiled-plan refactor removed.
loops=$(grep -rl "while remaining" src/repro)
if [ "$loops" != "src/repro/core/plan.py" ]; then
    echo "ready-loop guard failed: expected only src/repro/core/plan.py," >&2
    echo "found: $loops" >&2
    exit 1
fi

# Benchmark suite on tiny CPU-only shapes (includes the planner sweep
# over the two smallest configs) — schedule/planner regressions fail
# here, not just in tier-1.
PYTHONPATH=src python -m benchmarks.run --smoke > /dev/null

# Planner acceptance verdicts (paper Table 3): BPipe must win
# GPT-3-recompute and lose LLaMA.
PYTHONPATH=src python -m repro.launch.plan --config gpt3_96b \
    --attention recompute --top 0 \
    | grep -q 'PLAN gpt3-96b \[recompute\]: bpipe'
PYTHONPATH=src python -m repro.launch.plan --config llama_65b --top 0 \
    | grep -q 'PLAN llama-65b: 1f1b'

python -m pytest -q "$@"
